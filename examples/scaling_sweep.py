"""The paper's experiment, end to end: strong + weak scaling sweep of
DeepSpeed-style DP training across device counts — and dp x pp pipeline
layouts — on REAL devices (host platform devices via subprocess), plus the
analytic cluster projection.

    PYTHONPATH=src python examples/scaling_sweep.py --counts 1 2 4
    PYTHONPATH=src python examples/scaling_sweep.py --layouts 4x1 2x2

Each run consumes the trainer's ``--metrics-out`` JSON (step-level loss /
wall-clock history) instead of scraping stdout, and is seeded so repeated
sweeps are reproducible and layouts are loss-comparable.
"""
import argparse
import json
import os
import subprocess
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def run_train(devices: int, batch: int, steps: int = 8, *, pp: int = 1,
              accum: int = 1, seed: int = 0) -> dict:
    """One trainer subprocess -> {"wall_s", "final_loss", "history"}."""
    env = {**os.environ, "PYTHONPATH": os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "src")}
    with tempfile.NamedTemporaryFile(mode="r", suffix=".json",
                                     prefix="repro_sweep_") as f:
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.train", "--arch", "vit-b16",
             "--smoke", "--steps", str(steps), "--batch", str(batch),
             "--devices", str(devices), "--log-every", str(steps),
             "--pp", str(pp), "--accum", str(accum), "--seed", str(seed),
             "--metrics-out", f.name],
            env=env, capture_output=True, text=True)
        assert out.returncode == 0, out.stderr[-2000:]
        hist = json.load(f)
    assert hist, "trainer wrote no metrics history"
    return {"wall_s": hist[-1]["wall_s"], "final_loss": hist[-1]["loss"],
            "history": hist}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--counts", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--layouts", nargs="*", default=[],
                    help="dpxpp pipeline layouts (e.g. 4x1 2x2); device "
                         "count is dp*pp, accum is max(2, pp)")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default="/tmp/repro_scaling.json")
    args = ap.parse_args()

    results = {}
    print("== measured strong scaling (real host devices, fixed global "
          f"batch {args.batch}, seed {args.seed}) ==")
    for n in args.counts:
        r = run_train(n, args.batch, seed=args.seed)
        results[f"dp{n}"] = r["wall_s"]
        base = results[f"dp{args.counts[0]}"]
        print(f"  {n} devices: {r['wall_s']:6.1f}s  speedup "
              f"{base / r['wall_s']:.2f}x  final_loss {r['final_loss']:.4f}")

    if args.layouts:
        print("\n== dp x pp pipeline layouts (1F1B, fixed global batch) ==")
        ref_loss = None
        for layout in args.layouts:
            dp, pp = (int(x) for x in layout.split("x"))
            accum = max(2, pp)
            r = run_train(dp * pp, args.batch, pp=pp, accum=accum,
                          seed=args.seed)
            results[f"dp{dp}_pp{pp}"] = r["wall_s"]
            ref_loss = r["final_loss"] if ref_loss is None else ref_loss
            drift = abs(r["final_loss"] - ref_loss)
            print(f"  dp{dp} x pp{pp}: {r['wall_s']:6.1f}s  "
                  f"final_loss {r['final_loss']:.4f} "
                  f"(|Δ| vs first layout {drift:.1e})")

    print("\n== analytic projection to the paper's T4 cluster ==")
    from repro.core.comm_model import strong_scaling_times, weak_scaling_times
    t = strong_scaling_times(2.0, 344e6, [1, 2, 4, 8, 16, 32],
                             comm_bw=3.125e9)
    for n, ti in zip([1, 2, 4, 8, 16, 32], t):
        print(f"  {n:3d} GPUs: {ti:.3f}s/step  speedup {t[0]/ti:.2f}x")
    w = weak_scaling_times(2.0, 344e6, [1, 2, 4, 8], comm_bw=3.125e9)
    print(f"  weak scaling flatness: {max(w)/min(w):.2f}x")
    with open(args.json_out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"  results -> {args.json_out}")


if __name__ == "__main__":
    main()
