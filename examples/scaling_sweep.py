"""The paper's experiment, end to end: strong + weak scaling sweep of
DeepSpeed-style DP training across device counts, on REAL devices (host
platform devices via subprocess), plus the analytic cluster projection.

    PYTHONPATH=src python examples/scaling_sweep.py --counts 1 2 4
"""
import argparse
import json
import os
import re
import subprocess
import sys
import time


def run_train(devices: int, batch: int, steps: int = 8) -> float:
    env = {**os.environ, "PYTHONPATH": "src"}
    t0 = time.time()
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "vit-b16",
         "--smoke", "--steps", str(steps), "--batch", str(batch),
         "--devices", str(devices), "--log-every", str(steps)],
        env=env, capture_output=True, text=True)
    assert out.returncode == 0, out.stderr[-2000:]
    m = re.search(r"done in ([0-9.]+)s", out.stdout)
    return float(m.group(1)) if m else time.time() - t0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--counts", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--batch", type=int, default=32)
    args = ap.parse_args()

    print("== measured strong scaling (real host devices, fixed global "
          f"batch {args.batch}) ==")
    results = {}
    for n in args.counts:
        dt = run_train(n, args.batch)
        results[n] = dt
        base = results[args.counts[0]]
        print(f"  {n} devices: {dt:6.1f}s  speedup {base/dt:.2f}x")

    print("\n== analytic projection to the paper's T4 cluster ==")
    from repro.core.comm_model import strong_scaling_times, weak_scaling_times
    t = strong_scaling_times(2.0, 344e6, [1, 2, 4, 8, 16, 32],
                             comm_bw=3.125e9)
    for n, ti in zip([1, 2, 4, 8, 16, 32], t):
        print(f"  {n:3d} GPUs: {ti:.3f}s/step  speedup {t[0]/ti:.2f}x")
    w = weak_scaling_times(2.0, 344e6, [1, 2, 4, 8], comm_bw=3.125e9)
    print(f"  weak scaling flatness: {max(w)/min(w):.2f}x")
    json.dump({str(k): v for k, v in results.items()},
              open("/tmp/repro_scaling.json", "w"))


if __name__ == "__main__":
    main()
