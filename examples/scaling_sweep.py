"""The paper's experiment, end to end: strong + weak scaling sweep of
DeepSpeed-style DP training across device counts — and dp x pp pipeline
layouts — on REAL devices (host platform devices via subprocess), plus the
analytic cluster projection.

    PYTHONPATH=src python examples/scaling_sweep.py --counts 1 2 4
    PYTHONPATH=src python examples/scaling_sweep.py --layouts 4x1 2x2

Each run consumes the trainer's ``--metrics-out`` JSON (step-level loss /
wall-clock history) instead of scraping stdout, and is seeded so repeated
sweeps are reproducible and layouts are loss-comparable.
"""
import argparse
import json
import os
import subprocess
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def run_train(devices: int, batch: int, steps: int = 8, *, pp: int = 1,
              accum: int = 1, interleave: int = 1, layers: int = 0,
              seed: int = 0) -> dict:
    """One trainer subprocess -> {"wall_s", "final_loss", "history"}."""
    env = {**os.environ, "PYTHONPATH": os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "src")}
    with tempfile.NamedTemporaryFile(mode="r", suffix=".json",
                                     prefix="repro_sweep_") as f:
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.train", "--arch", "vit-b16",
             "--smoke", "--steps", str(steps), "--batch", str(batch),
             "--devices", str(devices), "--log-every", str(steps),
             "--pp", str(pp), "--accum", str(accum),
             "--pp-interleave", str(interleave), "--seed", str(seed),
             "--layers", str(layers), "--metrics-out", f.name],
            env=env, capture_output=True, text=True)
        assert out.returncode == 0, out.stderr[-2000:]
        hist = json.load(f)
    assert hist, "trainer wrote no metrics history"
    return {"wall_s": hist[-1]["wall_s"], "final_loss": hist[-1]["loss"],
            "history": hist}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--counts", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--layouts", nargs="*", default=[],
                    help="dp x pp (x interleave) pipeline layouts — "
                         "'4x1', '2x2', '2x2x2' (= dp2_pp2_v2), "
                         "'1x4x2' (= dp1_pp4_v2); device count is dp*pp, "
                         "accum is max(2, pp), layers pad to pp*v")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default="/tmp/repro_scaling.json")
    args = ap.parse_args()

    results = {}
    print("== measured strong scaling (real host devices, fixed global "
          f"batch {args.batch}, seed {args.seed}) ==")
    for n in args.counts:
        r = run_train(n, args.batch, seed=args.seed)
        results[f"dp{n}"] = r["wall_s"]
        base = results[f"dp{args.counts[0]}"]
        print(f"  {n} devices: {r['wall_s']:6.1f}s  speedup "
              f"{base / r['wall_s']:.2f}x  final_loss {r['final_loss']:.4f}")

    if args.layouts:
        from repro.core.pipeline import simulated_bubble_fraction
        print("\n== dp x pp (x v) pipeline layouts (1F1B, fixed global "
              "batch) ==")
        ref_loss = None
        for layout in args.layouts:
            parts = [int(x) for x in layout.split("x")]
            (dp, pp), v = parts[:2], parts[2] if len(parts) > 2 else 1
            accum = max(2, pp)
            # the smoke config's 2-layer stack only splits into pp*v
            # chunks when that divides it — pad the stack otherwise
            layers = pp * v if pp * v > 2 else 0
            r = run_train(dp * pp, args.batch, pp=pp, accum=accum,
                          interleave=v, layers=layers, seed=args.seed)
            name = f"dp{dp}_pp{pp}" + (f"_v{v}" if v > 1 else "")
            results[name] = r["wall_s"]
            # bubble read off the (interleaved) schedule simulator, not
            # the flat analytic formula — they differ once v > 1
            bubble = simulated_bubble_fraction(accum, pp, v) \
                if pp > 1 else 0.0
            results[f"{name}_bubble"] = bubble
            ref_loss = r["final_loss"] if ref_loss is None else ref_loss
            drift = abs(r["final_loss"] - ref_loss)
            print(f"  {name}: {r['wall_s']:6.1f}s  bubble {bubble:.3f}  "
                  f"final_loss {r['final_loss']:.4f} "
                  f"(|Δ| vs first layout {drift:.1e})")

    print("\n== analytic projection to the paper's T4 cluster ==")
    from repro.core.comm_model import strong_scaling_times, weak_scaling_times
    t = strong_scaling_times(2.0, 344e6, [1, 2, 4, 8, 16, 32],
                             comm_bw=3.125e9)
    for n, ti in zip([1, 2, 4, 8, 16, 32], t):
        print(f"  {n:3d} GPUs: {ti:.3f}s/step  speedup {t[0]/ti:.2f}x")
    w = weak_scaling_times(2.0, 344e6, [1, 2, 4, 8], comm_bw=3.125e9)
    print(f"  weak scaling flatness: {max(w)/min(w):.2f}x")
    with open(args.json_out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"  results -> {args.json_out}")


if __name__ == "__main__":
    main()
