"""Serving example: batched prefill + greedy decode with a KV/state cache,
on three different architecture families (attention / SSM / hybrid).

    PYTHONPATH=src python examples/serve_decode.py
"""
import os
import subprocess
import sys


def main():
    env = {**os.environ, "PYTHONPATH": "src"}
    for arch in ("qwen2.5-14b", "rwkv6-7b", "zamba2-2.7b"):
        print(f"\n=== {arch} ===")
        r = subprocess.call(
            [sys.executable, "-m", "repro.launch.serve", "--arch", arch,
             "--smoke", "--batch", "2", "--prompt-len", "32",
             "--gen", "16"], env=env)
        if r:
            sys.exit(r)


if __name__ == "__main__":
    main()
