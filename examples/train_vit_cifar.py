"""End-to-end driver (deliverable b): the paper's actual workload — ViT-B/16
(86M params, the "~100M model") trained on CIFAR-10/100 with data
parallelism, on-device augmentation, periodic held-out evaluation, elastic
checkpointing, and a metrics log.

Full-size invocation (what a TPU/GPU host would run, with the real data):
    PYTHONPATH=src python examples/train_vit_cifar.py --full --steps 300 \
        --devices 8 --batch 64 --accum 2 --data-dir /data/cifar \
        --augment --eval-every 50

Default (CPU-friendly) runs the reduced ViT at the same code path on the
deterministic procedural CIFAR stream — no downloads:
    PYTHONPATH=src python examples/train_vit_cifar.py

``--data-dir`` should hold the standard pickle distribution
(``cifar-10-batches-py/`` or ``cifar-100-python/``); when absent the
procedural generator stands in, batch-for-batch addressable by the same
``(seed, epoch, index)`` cursor.

Preemption / resume: checkpoints are the full TrainState (params, optimizer
moments, step, data cursor, rng) saved shard-locally every --ckpt-every
steps by the async saver. Kill the run at any point and re-invoke with
--resume to continue the exact loss trajectory — including the
augmentation stream (keyed on fold_in(state.rng, step)) and the eval
metrics — in the SAME layout or a different one (the restore reshards;
e.g. interrupt a --devices 8 DDP run and resume under --devices 4 --zero 3):

    PYTHONPATH=src python examples/train_vit_cifar.py --steps 120
    # ... preempted at step 60 ...
    PYTHONPATH=src python examples/train_vit_cifar.py --steps 120 --resume \
        --devices 4 --zero 3
"""
import argparse
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="true ViT-B/16 @224 (slow on CPU)")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--accum", type=int, default=2)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--dataset", default="cifar10",
                    choices=["cifar10", "cifar100"])
    ap.add_argument("--data-dir", default="",
                    help="real CIFAR binary batches (pickle distribution); "
                         "unset -> deterministic procedural CIFAR")
    ap.add_argument("--augment", action="store_true",
                    help="on-device RandomCrop+Flip+Mixup/CutMix")
    ap.add_argument("--eval-every", type=int, default=40,
                    help="held-out eval cadence in steps (0 = end only)")
    ap.add_argument("--zero", type=int, default=0)
    ap.add_argument("--ckpt-every", type=int, default=20,
                    help="async TrainState save cadence (steps)")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the latest checkpoint (any saved "
                         "layout restores into this run's layout)")
    args = ap.parse_args()

    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "vit-b16",
           "--steps", str(args.steps), "--batch", str(args.batch),
           "--accum", str(args.accum), "--zero", str(args.zero),
           "--dataset", args.dataset,
           "--eval-every", str(args.eval_every),
           "--label-smoothing", "0.1",
           "--ckpt-dir", "/tmp/repro_vit_ckpt",
           "--ckpt-every", str(args.ckpt_every),
           "--metrics-out", "/tmp/repro_vit_metrics.json",
           "--log-every", "20"]
    if not args.full:
        cmd.append("--smoke")
    if args.devices:
        cmd += ["--devices", str(args.devices)]
    if args.data_dir:
        cmd += ["--data-dir", args.data_dir]
    if args.augment:
        cmd.append("--augment")
    if args.resume:
        cmd.append("--resume")
    print("->", " ".join(cmd))
    sys.exit(subprocess.call(cmd, env={**__import__("os").environ,
                                       "PYTHONPATH": "src"}))


if __name__ == "__main__":
    main()
