"""Quickstart: train a reduced ViT-B/16 on synthetic CIFAR-10 with the
DeepSpeed-equivalent engine (DDP + gradient accumulation), ~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import EngineConfig, get_smoke_config
from repro.core.engine import DistributedEngine
from repro.data import DATASETS, DataPipeline
from repro.launch.mesh import make_local_mesh


def main():
    cfg = get_smoke_config("vit-b16").replace(dtype="float32")
    mesh = make_local_mesh()

    # the paper's Appendix-B style config
    ecfg = EngineConfig(
        train_batch_size=32,
        gradient_accumulation_steps=2,   # paper §IV: micro-batching knob
        zero_stage=0,                    # paper-faithful DDP
        optimizer="adamw",
        lr=1e-3, total_steps=40, warmup_steps=4,
    )
    engine = DistributedEngine(cfg, ecfg, mesh)
    pipe = DataPipeline(kind="image", global_batch=32,
                        dataset=DATASETS["cifar10"],
                        resolution=cfg.image_size)

    state = engine.init_state(seed=0)          # params+opt+step+cursor+rng
    train_step = engine.jit_train_step(donate=False)

    print(f"model={cfg.name} params={cfg.param_count()/1e6:.2f}M "
          f"devices={mesh.devices.size}")
    with mesh:
        for step, batch in enumerate(pipe.batches()):
            if step >= 40:
                break
            batch = jax.tree.map(jnp.asarray, batch)
            state, m = train_step(state, batch)
            if step % 10 == 0 or step == 39:
                print(f"step {step:3d}  loss {float(m['loss']):.4f}  "
                      f"acc {float(m['acc']):.3f}  lr {float(m['lr']):.1e}")
    print("done — loss should be well below the initial ~2.3")


if __name__ == "__main__":
    main()
