"""Quickstart: train a reduced ViT-B/16 on CIFAR-10 with the
DeepSpeed-equivalent engine (DDP + gradient accumulation + on-device
augmentation), then evaluate on the held-out split — ~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py

Without a downloaded dataset this runs the deterministic *procedural*
CIFAR stream (same shapes/statistics, no network); point REPRO_DATA_DIR
at a directory holding ``cifar-10-batches-py/`` to train on the real
binary batches through the identical code path.
"""
import os

from repro.configs import EngineConfig, get_smoke_config
from repro.core.engine import DistributedEngine
from repro.data import AugmentConfig, CIFARSource, DataPipeline
from repro.launch.mesh import make_local_mesh


def main():
    cfg = get_smoke_config("vit-b16").replace(dtype="float32",
                                              label_smoothing=0.1)
    mesh = make_local_mesh()

    # the paper's Appendix-B style config
    ecfg = EngineConfig(
        train_batch_size=32,
        gradient_accumulation_steps=2,   # paper §IV: micro-batching knob
        zero_stage=0,                    # paper-faithful DDP
        optimizer="adamw",
        lr=1e-3, total_steps=40, warmup_steps=4,
    )
    # real CIFAR-10 if REPRO_DATA_DIR has it, procedural otherwise; the
    # source ships uint8 batches — 4x fewer host->device bytes than fp32
    source = CIFARSource("cifar10", data_dir=os.environ.get("REPRO_DATA_DIR"),
                         resolution=cfg.image_size)
    pipe = DataPipeline(kind="image", global_batch=32, source=source)

    # RandomCrop+Flip+Mixup/CutMix, applied on-device inside the jitted
    # step (rng-threaded from the TrainState -> resumable stream);
    # preproc=source.preproc is the other half of the uint8 data path:
    # the jitted step upsamples + normalizes the raw bytes on device
    aug = AugmentConfig(num_classes=cfg.num_classes)
    engine = DistributedEngine(cfg, ecfg, mesh, aug=aug,
                               preproc=source.preproc)

    state = engine.init_state(seed=0)          # params+opt+step+cursor+rng
    train_step = engine.jit_train_step(donate=False)

    print(f"model={cfg.name} params={cfg.param_count()/1e6:.2f}M "
          f"devices={mesh.devices.size} "
          f"data={'procedural' if source.procedural else 'disk'} "
          f"train={source.train_size} eval={source.eval_size}")
    with mesh:
        e, i = 0, 0
        for step in range(40):
            batch = pipe.device_put(pipe.batch_at(e, i))
            e, i = pipe.next_cursor(e, i)
            state, m = train_step(state, batch)
            if step % 10 == 0 or step == 39:
                print(f"step {step:3d}  loss {float(m['loss']):.4f}  "
                      f"acc {float(m['acc']):.3f}  lr {float(m['lr']):.1e}")

    # sharded eval over the held-out split: integer top-1/top-5 counts
    # (exactly layout-invariant) + NLL, padded final batch masked out
    res = engine.evaluate(state, source.eval_batches(32))
    print(f"eval: top1 {res['eval_acc']:.3f} "
          f"({res['eval_top1_count']}/{res['eval_count']})  "
          f"top5 {res['eval_top5_acc']:.3f}  loss {res['eval_loss']:.4f}")
    print("done — loss should be well below the initial ~2.3")


if __name__ == "__main__":
    main()
