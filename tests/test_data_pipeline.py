"""Data pipeline determinism: batch seeds must be identical across launcher
processes (regression for the PYTHONHASHSEED-dependent hash() mix)."""
import subprocess
import sys

import numpy as np
import pytest

from repro.data import DATASETS, DataPipeline
from repro.data.pipeline import batch_seed


def test_batch_seed_is_process_stable():
    """crc32 is defined by the byte stream alone — these constants must
    never change, or two launcher ranks stop agreeing on "the same" batch."""
    assert batch_seed(0, 0, 0) == 599902752
    assert batch_seed(0, 0, 1) == 1869335230
    assert batch_seed(7, 3, 11) == 1719358963


def test_batch_seed_varies_over_epoch_and_step():
    seeds = {batch_seed(0, e, i) for e in range(4) for i in range(16)}
    assert len(seeds) == 64


def test_two_pipelines_generate_identical_batches():
    mk = lambda: DataPipeline(kind="image", global_batch=8, seed=3,
                              dataset=DATASETS["cifar10"], epoch_size=32)
    for a, b in zip(mk().batches(epoch=1), mk().batches(epoch=1)):
        np.testing.assert_array_equal(a["images"], b["images"])
        np.testing.assert_array_equal(a["labels"], b["labels"])


@pytest.mark.slow
def test_batches_identical_across_hashseed_processes(tmp_path):
    """The actual multi-process launcher scenario: two processes with
    different PYTHONHASHSEED must produce bit-identical first batches."""
    import os
    code = (
        "import os; os.environ.setdefault('JAX_PLATFORMS','cpu')\n"
        "import numpy as np\n"
        "from repro.data import DATASETS, DataPipeline\n"
        "p = DataPipeline(kind='image', global_batch=8, seed=0,\n"
        "                 dataset=DATASETS['cifar10'], epoch_size=16)\n"
        "b = next(iter(p.batches()))\n"
        "print(np.asarray(b['images']).sum(), b['labels'].tolist())\n"
    )
    outs = []
    for hashseed in ("1", "4242"):
        env = dict(os.environ, PYTHONHASHSEED=hashseed,
                   PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
        r = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True, timeout=300)
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout)
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# cursor-addressable batches + background prefetcher (the TrainState data
# cursor contract: (epoch, batch_index) names an exact batch)
# ---------------------------------------------------------------------------

def _pipe(epoch_size=32):
    return DataPipeline(kind="image", global_batch=8, seed=5,
                        dataset=DATASETS["cifar10"], epoch_size=epoch_size)


def test_batch_at_matches_batches_iterator():
    p = _pipe()
    for i, b in enumerate(p.batches(epoch=2)):
        ref = p.batch_at(2, i)
        np.testing.assert_array_equal(b["images"], ref["images"])
        np.testing.assert_array_equal(b["labels"], ref["labels"])
    with pytest.raises(IndexError):
        p.batch_at(0, p.steps_per_epoch)


def test_next_cursor_rolls_real_epochs():
    """Epoch rollover must advance the epoch counter (not reuse a step
    count), so batch seeds never collide across epochs."""
    p = _pipe()
    spe = p.steps_per_epoch
    assert p.next_cursor(0, 0) == (0, 1)
    assert p.next_cursor(0, spe - 1) == (1, 0)
    assert p.next_cursor(7, spe - 1) == (8, 0)
    seeds = {batch_seed(p.seed, e, i) for e in range(3) for i in range(spe)}
    assert len(seeds) == 3 * spe


def test_prefetcher_matches_sync_stream_and_rolls_epochs():
    """The background prefetcher yields the identical batch stream as
    synchronous cursor fetches, including across an epoch boundary, and
    reports the cursor a post-step checkpoint must record."""
    p = _pipe(epoch_size=24)            # 3 steps/epoch
    n = 7                               # crosses two epoch boundaries
    with p.prefetch(0, 1) as pf:        # start mid-epoch, like a resume
        got = [next(pf) for _ in range(n)]
    cur = (0, 1)
    for cursor, batch, nxt in got:
        assert cursor == cur
        ref = p.batch_at(*cursor)
        np.testing.assert_array_equal(np.asarray(batch["images"]),
                                      ref["images"])
        assert nxt == p.next_cursor(*cursor)
        cur = nxt
    assert got[-1][0] == (2, 1)


def test_prefetcher_propagates_synthesis_errors():
    p = _pipe()
    p.dataset = None                    # synthesis will blow up
    with p.prefetch(0, 0) as pf:
        with pytest.raises(RuntimeError, match="prefetch thread failed"):
            next(pf)
