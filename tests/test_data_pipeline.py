"""Data pipeline determinism: batch seeds must be identical across launcher
processes (regression for the PYTHONHASHSEED-dependent hash() mix)."""
import subprocess
import sys

import numpy as np
import pytest

from repro.data import DATASETS, DataPipeline
from repro.data.pipeline import batch_seed


def test_batch_seed_is_process_stable():
    """crc32 is defined by the byte stream alone — these constants must
    never change, or two launcher ranks stop agreeing on "the same" batch."""
    assert batch_seed(0, 0, 0) == 599902752
    assert batch_seed(0, 0, 1) == 1869335230
    assert batch_seed(7, 3, 11) == 1719358963


def test_batch_seed_varies_over_epoch_and_step():
    seeds = {batch_seed(0, e, i) for e in range(4) for i in range(16)}
    assert len(seeds) == 64


def test_two_pipelines_generate_identical_batches():
    mk = lambda: DataPipeline(kind="image", global_batch=8, seed=3,
                              dataset=DATASETS["cifar10"], epoch_size=32)
    for a, b in zip(mk().batches(epoch=1), mk().batches(epoch=1)):
        np.testing.assert_array_equal(a["images"], b["images"])
        np.testing.assert_array_equal(a["labels"], b["labels"])


@pytest.mark.slow
def test_batches_identical_across_hashseed_processes(tmp_path):
    """The actual multi-process launcher scenario: two processes with
    different PYTHONHASHSEED must produce bit-identical first batches."""
    import os
    code = (
        "import os; os.environ.setdefault('JAX_PLATFORMS','cpu')\n"
        "import numpy as np\n"
        "from repro.data import DATASETS, DataPipeline\n"
        "p = DataPipeline(kind='image', global_batch=8, seed=0,\n"
        "                 dataset=DATASETS['cifar10'], epoch_size=16)\n"
        "b = next(iter(p.batches()))\n"
        "print(np.asarray(b['images']).sum(), b['labels'].tolist())\n"
    )
    outs = []
    for hashseed in ("1", "4242"):
        env = dict(os.environ, PYTHONHASHSEED=hashseed,
                   PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
        r = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True, timeout=300)
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout)
    assert outs[0] == outs[1]
