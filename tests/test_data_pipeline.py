"""Data pipeline determinism: batch seeds must be identical across launcher
processes (regression for the PYTHONHASHSEED-dependent hash() mix), the
CIFAR source behind the cursor contract, on-device augmentation properties
(hypothesis), and the Prefetcher thread-lifecycle regressions."""
import gc
import pickle
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.data import CIFARSource, DATASETS, DataPipeline
from repro.data.pipeline import batch_seed


def test_batch_seed_is_process_stable():
    """crc32 is defined by the byte stream alone — these constants must
    never change, or two launcher ranks stop agreeing on "the same" batch."""
    assert batch_seed(0, 0, 0) == 599902752
    assert batch_seed(0, 0, 1) == 1869335230
    assert batch_seed(7, 3, 11) == 1719358963


def test_batch_seed_varies_over_epoch_and_step():
    seeds = {batch_seed(0, e, i) for e in range(4) for i in range(16)}
    assert len(seeds) == 64


def test_two_pipelines_generate_identical_batches():
    mk = lambda: DataPipeline(kind="image", global_batch=8, seed=3,
                              dataset=DATASETS["cifar10"], epoch_size=32)
    for a, b in zip(mk().batches(epoch=1), mk().batches(epoch=1)):
        np.testing.assert_array_equal(a["images"], b["images"])
        np.testing.assert_array_equal(a["labels"], b["labels"])


@pytest.mark.slow
def test_batches_identical_across_hashseed_processes(tmp_path):
    """The actual multi-process launcher scenario: two processes with
    different PYTHONHASHSEED must produce bit-identical first batches."""
    import os
    code = (
        "import os; os.environ.setdefault('JAX_PLATFORMS','cpu')\n"
        "import numpy as np\n"
        "from repro.data import DATASETS, DataPipeline\n"
        "p = DataPipeline(kind='image', global_batch=8, seed=0,\n"
        "                 dataset=DATASETS['cifar10'], epoch_size=16)\n"
        "b = next(iter(p.batches()))\n"
        "print(np.asarray(b['images']).sum(), b['labels'].tolist())\n"
    )
    outs = []
    for hashseed in ("1", "4242"):
        env = dict(os.environ, PYTHONHASHSEED=hashseed,
                   PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
        r = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True, timeout=300)
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout)
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# cursor-addressable batches + background prefetcher (the TrainState data
# cursor contract: (epoch, batch_index) names an exact batch)
# ---------------------------------------------------------------------------

def _pipe(epoch_size=32):
    return DataPipeline(kind="image", global_batch=8, seed=5,
                        dataset=DATASETS["cifar10"], epoch_size=epoch_size)


def test_batch_at_matches_batches_iterator():
    p = _pipe()
    for i, b in enumerate(p.batches(epoch=2)):
        ref = p.batch_at(2, i)
        np.testing.assert_array_equal(b["images"], ref["images"])
        np.testing.assert_array_equal(b["labels"], ref["labels"])
    with pytest.raises(IndexError):
        p.batch_at(0, p.steps_per_epoch)


def test_next_cursor_rolls_real_epochs():
    """Epoch rollover must advance the epoch counter (not reuse a step
    count), so batch seeds never collide across epochs."""
    p = _pipe()
    spe = p.steps_per_epoch
    assert p.next_cursor(0, 0) == (0, 1)
    assert p.next_cursor(0, spe - 1) == (1, 0)
    assert p.next_cursor(7, spe - 1) == (8, 0)
    seeds = {batch_seed(p.seed, e, i) for e in range(3) for i in range(spe)}
    assert len(seeds) == 3 * spe


def test_prefetcher_matches_sync_stream_and_rolls_epochs():
    """The background prefetcher yields the identical batch stream as
    synchronous cursor fetches, including across an epoch boundary, and
    reports the cursor a post-step checkpoint must record."""
    p = _pipe(epoch_size=24)            # 3 steps/epoch
    n = 7                               # crosses two epoch boundaries
    with p.prefetch(0, 1) as pf:        # start mid-epoch, like a resume
        got = [next(pf) for _ in range(n)]
    cur = (0, 1)
    for cursor, batch, nxt in got:
        assert cursor == cur
        ref = p.batch_at(*cursor)
        np.testing.assert_array_equal(np.asarray(batch["images"]),
                                      ref["images"])
        assert nxt == p.next_cursor(*cursor)
        cur = nxt
    assert got[-1][0] == (2, 1)


def test_prefetcher_propagates_synthesis_errors():
    p = _pipe()
    p.dataset = None                    # synthesis will blow up
    with p.prefetch(0, 0) as pf:
        with pytest.raises(RuntimeError, match="prefetch thread failed"):
            next(pf)


# ---------------------------------------------------------------------------
# CIFAR source (data/datasets.py): procedural determinism, the disk loader
# against synthesized pickle batches, and eval-split padding
# ---------------------------------------------------------------------------

def test_procedural_source_is_deterministic():
    """Two independently-constructed sources with the same seed agree on
    BOTH splits byte-for-byte — the cross-process/layout contract."""
    a = CIFARSource("cifar10", seed=9, eval_size=40)
    b = CIFARSource("cifar10", seed=9, eval_size=40)
    np.testing.assert_array_equal(a._eval_images, b._eval_images)
    np.testing.assert_array_equal(a._eval_labels, b._eval_labels)
    ba = a.train_batch(8, seed=123)
    bb = b.train_batch(8, seed=123)
    np.testing.assert_array_equal(ba["images"], bb["images"])
    np.testing.assert_array_equal(ba["labels"], bb["labels"])
    # different seeds -> different eval data
    c = CIFARSource("cifar10", seed=10, eval_size=40)
    assert not np.array_equal(a._eval_images, c._eval_images)


def test_source_behind_pipeline_cursor_contract():
    """batch_at(epoch, index) through a CIFARSource is pure in
    (seed, epoch, index) — the elastic-resume addressability contract."""
    mk = lambda: DataPipeline(kind="image", global_batch=4, seed=7,
                              source=CIFARSource("cifar10", seed=7,
                                                 eval_size=16))
    p1, p2 = mk(), mk()
    for e, i in ((0, 0), (0, 3), (2, 1)):
        b1, b2 = p1.batch_at(e, i), p2.batch_at(e, i)
        np.testing.assert_array_equal(b1["images"], b2["images"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])
    assert not np.array_equal(p1.batch_at(0, 0)["labels"],
                              p1.batch_at(1, 0)["labels"]) or \
        not np.array_equal(p1.batch_at(0, 0)["images"],
                           p1.batch_at(1, 0)["images"])


def test_batch_shapes_uint8_native_with_source_fp32_without():
    """A source-backed pipeline declares uint8 batches at the NATIVE grid
    (what actually crosses host->device — 4x fewer bytes); the legacy
    synthetic stream stays pre-normalized fp32 at the model resolution."""
    src = CIFARSource("cifar10", seed=0, resolution=64, eval_size=16)
    shp = DataPipeline(kind="image", global_batch=8, source=src,
                       seed=0).batch_shapes()
    assert shp["images"].shape == (8, 32, 32, 3)
    assert shp["images"].dtype == np.uint8
    shp = _pipe().batch_shapes()
    assert shp["images"].shape == (8, 32, 32, 3)
    assert shp["images"].dtype == np.float32


def _write_fake_cifar10(root):
    """Tiny but format-faithful cifar-10-batches-py distribution."""
    d = root / "cifar-10-batches-py"
    d.mkdir()
    rng = np.random.default_rng(0)
    for name, n in [(f"data_batch_{i}", 4) for i in range(1, 6)] + \
            [("test_batch", 10)]:
        data = rng.integers(0, 256, (n, 3072), dtype=np.uint8)
        labels = rng.integers(0, 10, (n,)).tolist()
        with open(d / name, "wb") as f:
            pickle.dump({b"data": data, b"labels": labels}, f)
    return data  # the test batch's raw rows


def test_disk_loader_reads_pickle_batches(tmp_path):
    raw_test = _write_fake_cifar10(tmp_path)
    src = CIFARSource("cifar10", data_dir=str(tmp_path), seed=0)
    assert not src.procedural
    assert src.train_size == 20 and src.eval_size == 10
    # splits stay RAW uint8 (the 4x-smaller resident copy; normalization
    # happens on device) — the stored bytes are exactly the pickle rows
    assert src._eval_images.dtype == np.uint8
    img0 = raw_test[0].reshape(3, 32, 32).transpose(1, 2, 0)
    np.testing.assert_array_equal(src._eval_images[0], img0)
    b = src.train_batch(6, seed=5)
    assert b["images"].shape == (6, 32, 32, 3)
    assert b["images"].dtype == np.uint8
    assert b["labels"].dtype == np.int32
    # purity in seed holds for the disk path too
    b2 = src.train_batch(6, seed=5)
    np.testing.assert_array_equal(b["images"], b2["images"])


def test_eval_stays_native_and_device_upsamples(tmp_path):
    """The host never upsamples: eval batches leave at the native 32px
    uint8 grid, and the DEVICE half (device_preprocess) produces the
    model-resolution normalized fp32 tensor with nearest-neighbor
    blocks."""
    import jax.numpy as jnp
    from repro.data.augment import device_preprocess
    _write_fake_cifar10(tmp_path)
    src = CIFARSource("cifar10", data_dir=str(tmp_path), resolution=64)
    b = next(src.eval_batches(4))
    assert b["images"].shape == (4, 32, 32, 3)
    assert b["images"].dtype == np.uint8
    out = device_preprocess(dict(b), src.preproc, 64)
    assert out["images"].shape == (4, 64, 64, 3)
    assert out["images"].dtype == jnp.float32
    # nearest-neighbor: each native pixel becomes a constant 2x2 block
    np.testing.assert_array_equal(np.asarray(out["images"][0, 0, 0]),
                                  np.asarray(out["images"][0, 1, 1]))


def test_weak_scaling_pool_restricts_sampled_indices(tmp_path):
    """§IV-A regression: weak_scaling_frac must restrict the disk-mode
    SAMPLED pool, not just shorten the epoch — every drawn example must
    come from the first frac-of-the-split slice."""
    _write_fake_cifar10(tmp_path)
    src = CIFARSource("cifar10", data_dir=str(tmp_path), seed=0)
    p = DataPipeline(kind="image", global_batch=8, seed=3, source=src,
                     weak_scaling_frac=0.25)
    assert p.sample_pool == 5           # 20 * 0.25
    allowed = src._train_images[:5]
    for i in range(p.steps_per_epoch):
        for img in p.batch_at(0, i)["images"]:
            assert any(np.array_equal(img, a) for a in allowed)
    # frac=1.0 derives no pool at all (full-split sampling)
    assert DataPipeline(kind="image", global_batch=8, source=src,
                        seed=3).sample_pool is None
    # out-of-range pools are a wiring error, not a silent clamp
    with pytest.raises(ValueError, match="out of range"):
        src.train_batch(4, seed=0, pool=999)


def test_local_shard_rejects_non_divisible_batch():
    """local_shard used to silently truncate (per = B // world); now a
    non-divisible global batch raises, naming both numbers."""
    p = _pipe()
    batch = {"images": np.zeros((10, 4, 4, 3)), "labels": np.zeros((10,))}
    with pytest.raises(ValueError, match="10.*world size 4"):
        p.local_shard(batch, 0, 4)
    shard = p.local_shard(batch, 1, 2)
    assert shard["images"].shape[0] == 5


def test_eval_batches_pad_final_batch_with_mask():
    src = CIFARSource("cifar10", seed=1, eval_size=21)
    batches = list(src.eval_batches(8))
    assert len(batches) == 3 == src.num_eval_batches(8)
    for b in batches:
        assert b["images"].shape == (8, 32, 32, 3)
        assert b["mask"].shape == (8,)
    np.testing.assert_array_equal(batches[0]["mask"], np.ones(8))
    np.testing.assert_array_equal(batches[2]["mask"],
                                  [1, 1, 1, 1, 1, 0, 0, 0])
    # padded tail is zeroed (metric-invisible under the mask)
    assert np.all(batches[2]["images"][5:] == 0.0)
    # concatenating the masked examples reproduces the split exactly
    got = np.concatenate([b["labels"][b["mask"] > 0] for b in batches])
    np.testing.assert_array_equal(got, src._eval_labels)


def test_unknown_dataset_rejected():
    with pytest.raises(ValueError, match="unknown CIFAR dataset"):
        CIFARSource("imagenet100")


def test_explicit_data_dir_without_batches_raises(tmp_path):
    """An explicitly-given --data-dir that lacks the pickle batches must
    raise, NOT silently fall back to procedural data (a reproduction run
    reporting plausible metrics on fake data is the worst failure)."""
    with pytest.raises(FileNotFoundError, match="does not contain"):
        CIFARSource("cifar10", data_dir=str(tmp_path))
    # unset data_dir is the sanctioned procedural path
    assert CIFARSource("cifar10", data_dir=None).procedural


# ---------------------------------------------------------------------------
# Prefetcher thread lifecycle (regression: a producer error with a full
# queue — or a consumer that walks away — must never strand the thread)
# ---------------------------------------------------------------------------

def _wait_until(cond, timeout=5.0):
    deadline = time.time() + timeout
    while not cond() and time.time() < deadline:
        time.sleep(0.01)
    assert cond()


def test_prefetcher_close_terminates_thread_and_unblocks_consumer():
    p = _pipe()
    pf = p.prefetch(0, 0)
    next(pf)
    pf.close()
    assert not any(t.is_alive() for t in pf._threads)
    # next() after close must NOT block on the drained queue
    with pytest.raises(StopIteration):
        next(pf)
    pf.close()          # idempotent


def test_prefetcher_error_with_full_queue_does_not_strand_thread():
    """Producer raises while the queues are full and the consumer has
    stopped consuming — the old blocking error-put stranded the thread
    here; the stop-aware put lets close() reclaim both stages."""
    p = _pipe()
    orig = p.batch_at
    p.batch_at = lambda e, i: orig(e, i) if (e, i) == (0, 0) \
        else (_ for _ in ()).throw(ValueError("boom"))
    pf = p.prefetch(0, 0, depth=1)  # depth=1: first batch fills the
    #                   device queue, the forwarded error then meets a
    #                   FULL queue with nobody consuming
    _wait_until(lambda: pf._error is not None)
    assert any(t.is_alive() for t in pf._threads)   # parked in a put
    pf.close()
    assert not any(t.is_alive() for t in pf._threads)   # reclaimed


def test_prefetcher_dropped_reference_reclaims_thread():
    """Consumer walks away without close(): __del__ must stop BOTH
    stage threads instead of leaving them parked forever."""
    p = _pipe()
    pf = p.prefetch(0, 0)
    threads = pf._threads
    next(pf)
    del pf
    gc.collect()
    _wait_until(lambda: not any(t.is_alive() for t in threads))


def test_prefetcher_error_after_ok_items_still_propagates():
    """Error queued behind buffered ok items: the consumer sees the good
    batches first, then the RuntimeError, and the threads are gone."""
    p = _pipe()
    orig = p.batch_at
    p.batch_at = lambda e, i: orig(e, i) if i < 2 \
        else (_ for _ in ()).throw(ValueError("boom"))
    with p.prefetch(0, 0, depth=2) as pf:
        assert next(pf)[0] == (0, 0)
        assert next(pf)[0] == (0, 1)
        with pytest.raises(RuntimeError, match="prefetch thread failed"):
            next(pf)
    assert not any(t.is_alive() for t in pf._threads)


def test_prefetcher_depth_n_overlaps_and_preserves_order():
    """The two-stage N-deep pipeline (synthesis thread -> transfer
    thread) must yield the exact cursor-ordered stream — depth changes
    only overlap, never content or order."""
    p = _pipe(epoch_size=24)            # 3 steps/epoch
    with p.prefetch(0, 0, depth=4) as pf:
        assert {t.name for t in pf._threads} == \
            {"data-synth", "data-transfer"}
        got = [next(pf) for _ in range(8)]  # crosses epoch boundaries
    cur = (0, 0)
    for cursor, batch, nxt in got:
        assert cursor == cur
        np.testing.assert_array_equal(np.asarray(batch["images"]),
                                      p.batch_at(*cursor)["images"])
        assert nxt == p.next_cursor(*cursor)
        cur = nxt


def test_prefetcher_close_warns_on_hung_producer():
    """A producer that outlives the join timeout must be REPORTED (with
    the pending cursor), not silently leaked."""
    import threading
    release = threading.Event()
    p = _pipe()
    p.batch_at = lambda e, i: release.wait() and None    # wedged source
    pf = p.prefetch(0, 0, retry=None)
    pf.JOIN_TIMEOUT = 0.2
    with pytest.warns(RuntimeWarning,
                      match=r"pending cursor \(epoch 0, batch 0\)"):
        pf.close()
    release.set()                       # let the daemon thread die


def test_prefetch_depth_must_be_positive():
    with pytest.raises(ValueError, match="depth"):
        _pipe().prefetch(0, 0, depth=0)


def test_prefetcher_retries_transient_source_errors():
    """A flaky source (bounded run of OSError/TransientError) resolves
    behind the prefetch overlap — the consumer sees only good batches,
    in cursor order, never the transient failures."""
    from repro.resilience.backoff import BackoffPolicy, TransientError
    p = _pipe()
    orig = p.batch_at
    fails = {"n": 0}

    def flaky(e, i):
        if (e, i) == (0, 1) and fails["n"] < 2:
            fails["n"] += 1
            raise TransientError("network blip")
        return orig(e, i)

    p.batch_at = flaky
    retry = BackoffPolicy(max_attempts=3, base_delay=0.01, max_delay=0.01)
    with p.prefetch(0, 0, retry=retry) as pf:
        cursors = [next(pf)[0] for _ in range(3)]
    assert cursors == [(0, 0), (0, 1), (0, 2)]
    assert fails["n"] == 2


def test_prefetcher_exhausted_retries_propagate():
    """A PERSISTENT IO failure (outlives the retry budget) must reach
    the consumer, not spin forever in the producer."""
    from repro.resilience.backoff import BackoffPolicy, TransientError
    p = _pipe()
    calls = {"n": 0}

    def down(e, i):
        calls["n"] += 1
        raise TransientError("source is down")

    p.batch_at = down
    retry = BackoffPolicy(max_attempts=3, base_delay=0.01, max_delay=0.01)
    with p.prefetch(0, 0, retry=retry) as pf:
        with pytest.raises(RuntimeError, match="prefetch thread failed"):
            next(pf)
    assert calls["n"] == 3              # exactly the retry budget


def test_prefetcher_nonretryable_errors_skip_the_retry_loop():
    """Non-OSError synthesis bugs propagate on the FIRST attempt —
    retrying a deterministic exception only delays the report."""
    p = _pipe()
    calls = {"n": 0}

    def broken(e, i):
        calls["n"] += 1
        raise ValueError("synthesis bug")

    p.batch_at = broken
    with p.prefetch(0, 0) as pf:        # default retry policy active
        with pytest.raises(RuntimeError, match="prefetch thread failed"):
            next(pf)
    assert calls["n"] == 1


def test_prefetcher_close_interrupts_backoff_sleep():
    """Retry sleeps wait on the stop event: close() during a long
    backoff returns promptly instead of serving out the delay."""
    from repro.resilience.backoff import BackoffPolicy, TransientError
    p = _pipe()
    p.batch_at = lambda e, i: (_ for _ in ()).throw(
        TransientError("always down"))
    retry = BackoffPolicy(max_attempts=10, base_delay=30.0, max_delay=30.0)
    pf = p.prefetch(0, 0, retry=retry)
    _wait_until(lambda: any(t.is_alive() for t in pf._threads))
    t0 = time.time()
    pf.close()
    assert time.time() - t0 < 10        # not a 30s backoff serve-out
    assert not any(t.is_alive() for t in pf._threads)


def test_batch_at_data_fault_injection_roundtrip():
    """The chaos harness's `data` fault rides the same retry path: a
    transient plan resolves invisibly, a permanent one propagates."""
    from repro.resilience import FaultPlan, PermanentFault
    from repro.resilience.backoff import BackoffPolicy
    retry = BackoffPolicy(max_attempts=3, base_delay=0.01, max_delay=0.01)
    with FaultPlan.parse("data@1:transient:2"):
        p = _pipe()
        with p.prefetch(0, 0, retry=retry) as pf:
            cursors = [next(pf)[0] for _ in range(3)]
        assert cursors == [(0, 0), (0, 1), (0, 2)]
    with FaultPlan.parse("data@0:permanent"):
        p = _pipe()
        with p.prefetch(0, 0, retry=retry) as pf:
            with pytest.raises(RuntimeError,
                               match="prefetch thread failed"):
                next(pf)
