"""Checkpoint, data pipeline, schedules, HLO analyzer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.data import DATASETS, DataPipeline
from repro.data.synthetic import make_image_batch, make_token_batch
from repro.launch import hlo_analysis
from repro.optim import make_schedule


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
            "nested": {"b": jnp.ones((2,), jnp.bfloat16),
                       "c": jnp.int32(7)}}
    save_checkpoint(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5
    out = restore_checkpoint(str(tmp_path), 5, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_strict_key_mismatch(tmp_path):
    """Missing AND unexpected leaves must raise KeyError naming the
    offending paths — never a silent partial restore."""
    tree = {"a": jnp.zeros((2,)), "b": {"x": jnp.ones((3,))}}
    save_checkpoint(str(tmp_path), 1, tree)
    bad = {"a": jnp.zeros((2,)), "b": {"y": jnp.ones((3,))}}
    with pytest.raises(KeyError) as e:
        restore_checkpoint(str(tmp_path), 1, bad)
    assert "b/y" in str(e.value) and "b/x" in str(e.value)


def test_checkpoint_strict_shape_dtype_mismatch(tmp_path):
    """Shape/dtype drift must raise with BOTH sides printed (all offenders
    listed), not crash in a reshape."""
    tree = {"w": jnp.zeros((4, 4), jnp.float32),
            "s": jnp.zeros((2,), jnp.bfloat16)}
    save_checkpoint(str(tmp_path), 1, tree)
    bad = {"w": jnp.zeros((4, 8), jnp.float32),
           "s": jnp.zeros((2,), jnp.float32)}
    with pytest.raises(ValueError) as e:
        restore_checkpoint(str(tmp_path), 1, bad)
    msg = str(e.value)
    assert "w" in msg and "(4, 4)" in msg and "(4, 8)" in msg
    assert "s" in msg and "bfloat16" in msg and "float32" in msg


def test_async_checkpointer_roundtrip(tmp_path):
    """Async saves land complete (atomic rename: no *.tmp left behind),
    restore bit-identically, and respect the in-flight bound."""
    ckpt = AsyncCheckpointer(max_in_flight=2)
    trees = {}
    for step in (1, 2, 3):
        trees[step] = {"w": jnp.full((8, 8), float(step)),
                       "n": {"b": jnp.arange(step + 1)}}
        ckpt.save(str(tmp_path), step, trees[step])
    ckpt.wait()
    assert latest_step(str(tmp_path)) == 3
    assert not [d for d in os.listdir(tmp_path) if ".tmp" in d]
    for step in (1, 3):
        out = restore_checkpoint(str(tmp_path), step, trees[step])
        for a, b in zip(jax.tree.leaves(trees[step]), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpointer_surfaces_write_errors(tmp_path):
    """A failed background write must raise on wait(), not vanish."""
    ckpt = AsyncCheckpointer()
    # a FILE where the tmp staging dir must go -> background mkdir fails
    (tmp_path / "step_00000001.tmp").write_text("in the way")
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros(())})
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        ckpt.wait()


def test_async_checkpointer_fails_fast_on_next_save(tmp_path):
    """After a background failure the NEXT save must refuse immediately —
    a run must not keep training for another ckpt_every interval on top
    of a save path that is already broken."""
    ckpt = AsyncCheckpointer()
    (tmp_path / "step_00000001.tmp").write_text("in the way")
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros(())})
    for t in ckpt._pending:             # let the failure land
        t.join()
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        ckpt.save(str(tmp_path), 2, {"a": jnp.zeros(())})
    ckpt.close()                        # drains without raising


def test_async_checkpointer_close_logs_instead_of_raising(tmp_path,
                                                          capsys):
    """close()/__exit__-on-exception/__del__ must never RAISE a stored
    background failure (it would mask the in-flight exception) — but
    must never silently swallow it either: it is printed."""
    ckpt = AsyncCheckpointer()
    (tmp_path / "step_00000001.tmp").write_text("in the way")
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros(())})
    ckpt.close()                        # no raise
    out = capsys.readouterr().out
    assert "async checkpoint save failed" in out
    ckpt.wait()                         # close() cleared the error
    # __exit__ on an exceptional path takes the close() branch
    with pytest.raises(KeyError):
        with AsyncCheckpointer() as c2:
            (tmp_path / "step_00000002.tmp").write_text("in the way")
            c2.save(str(tmp_path), 2, {"a": jnp.zeros(())})
            raise KeyError("unrelated failure already in flight")
    assert "async checkpoint save failed" in capsys.readouterr().out


def test_data_determinism_and_structure():
    spec = DATASETS["cifar10"]
    b1 = make_image_batch(spec, 8, seed=3)
    b2 = make_image_batch(spec, 8, seed=3)
    np.testing.assert_array_equal(b1["images"], b2["images"])
    assert b1["images"].shape == (8, 32, 32, 3)
    assert (b1["labels"] < 10).all()
    t = make_token_batch(1000, 4, 16, seed=0)
    assert t["tokens"].shape == (4, 16) and (t["tokens"] < 1000).all()


def test_image_classes_are_separable():
    """Synthetic data must be learnable (paper's accuracy trends)."""
    spec = DATASETS["cifar10"]
    b = make_image_batch(spec, 256, seed=0)
    # nearest-template classification in pixel space beats chance by a lot
    from repro.data.synthetic import np as _np
    import numpy as np2
    rng = np2.random.default_rng(1234)
    templates = rng.normal(0, 1, (10, 8, 8, 3)).astype(np2.float32)
    reps = 32 // 8
    t_up = np2.tile(templates, (1, reps, reps, 1))
    d = ((b["images"][:, None] - t_up[None]) ** 2).sum((2, 3, 4))
    acc = (d.argmin(1) == b["labels"]).mean()
    assert acc > 0.9, acc


def test_weak_scaling_fraction():
    pipe_full = DataPipeline(kind="image", global_batch=64,
                             dataset=DATASETS["cifar10"])
    pipe_10 = DataPipeline(kind="image", global_batch=64,
                           dataset=DATASETS["cifar10"],
                           weak_scaling_frac=0.1)
    assert pipe_10.steps_per_epoch * 10 - pipe_full.steps_per_epoch <= 10
    shard = pipe_full.local_shard(next(iter(pipe_full.batches())), 1, 4)
    assert shard["images"].shape[0] == 16


def test_schedule_shapes():
    s = make_schedule("cosine", 1e-3, 10, 100)
    assert 0 < float(s(0)) <= 1.01e-4   # warmup starts at (step+1)
    assert abs(float(s(10)) - 1e-3) < 1e-9
    assert float(s(100)) < float(s(50)) < float(s(10))
    lin = make_schedule("linear", 1e-3, 0, 100)
    assert float(lin(100)) < float(lin(0)) * 0.2 + 1e-9


def test_hlo_analysis_counts_scan_trips():
    """Analyzer must multiply dot flops by scan trip count."""
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=7)
        return h

    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    hlo = jax.jit(f).lower(x, w).compile().as_text()
    t = hlo_analysis.analyze(hlo)
    expect = 7 * 2 * 8 * 16 * 16
    assert abs(t.flops - expect) / expect < 0.05, (t.flops, expect)


def test_hlo_analysis_single_matmul():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    hlo = jax.jit(f).lower(a, b).compile().as_text()
    t = hlo_analysis.analyze(hlo)
    expect = 2 * 32 * 64 * 128
    assert abs(t.flops - expect) / expect < 0.01


def test_top_contributors_runs():
    def f(a, b):
        def body(h, _):
            return h @ b, None
        h, _ = jax.lax.scan(body, a, None, length=5)
        return h
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    hlo = jax.jit(f).lower(a, b).compile().as_text()
    rows = hlo_analysis.top_contributors(hlo, n=5, by="flops")
    assert rows and rows[0][1] == 5.0   # trip multiplier visible
