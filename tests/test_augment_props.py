"""Property tests for data/augment.py (hypothesis): augmentation
determinism under the cursor contract (same (seed, epoch, index, step) =>
same batch after a resume rebuilds everything), Mixup/CutMix soft-label
convexity, and flip/crop label-invariance."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st  # noqa: E402

SETTINGS = dict(max_examples=20, deadline=None)


def _aug_cfg(**kw):
    from repro.data import AugmentConfig
    base = dict(num_classes=10)
    base.update(kw)
    return AugmentConfig(**base)


def _train_rng(seed, step, microbatch=0):
    """The engine's augmentation key derivation (core/engine.py):
    fold_in(base rng, step) split per microbatch — reproduced here from
    scratch, which is exactly what a resumed run does."""
    import jax
    base = jax.random.fold_in(jax.random.PRNGKey(seed), 1)  # init_state rng
    return jax.random.split(jax.random.fold_in(base, step), 4)[microbatch]


@settings(**SETTINGS)
@given(seed=st.integers(0, 2 ** 16), epoch=st.integers(0, 3),
       index=st.integers(0, 3), step=st.integers(0, 50))
def test_augmentation_deterministic_under_cursor_contract(
        seed, epoch, index, step):
    """Same (seed, epoch, index, step) => same augmented batch, with every
    object rebuilt from scratch between the two draws — the resume
    contract: a restored run replays the interrupted run's augmentation
    stream exactly."""
    from repro.data import CIFARSource, DataPipeline, augment_batch

    def draw():
        src = CIFARSource("cifar10", seed=seed, eval_size=8)
        pipe = DataPipeline(kind="image", global_batch=4, seed=seed,
                            source=src, epoch_size=16)
        batch = pipe.batch_at(epoch, index)       # uint8 at 32px
        import jax.numpy as jnp
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        return augment_batch(_train_rng(seed, step), batch, _aug_cfg(),
                             preproc=src.preproc, resolution=32)

    a, b = draw(), draw()
    np.testing.assert_array_equal(np.asarray(a["images"]),
                                  np.asarray(b["images"]))
    np.testing.assert_array_equal(np.asarray(a["labels"]),
                                  np.asarray(b["labels"]))
    assert np.asarray(a["images"]).dtype == np.float32  # normalized out


def test_uint8_batch_without_preproc_raises():
    import jax, jax.numpy as jnp
    from repro.data import augment_batch
    batch = {"images": jnp.zeros((4, 32, 32, 3), jnp.uint8),
             "labels": jnp.zeros((4,), jnp.int32)}
    with pytest.raises(ValueError, match="needs preproc"):
        augment_batch(jax.random.PRNGKey(0), batch, _aug_cfg())


@settings(**SETTINGS)
@given(seed=st.integers(0, 2 ** 16),
       mixup=st.sampled_from([0.0, 0.2, 1.0]),
       cutmix=st.sampled_from([0.0, 1.0]),
       switch=st.sampled_from([0.0, 0.5, 1.0]))
def test_mix_label_convexity(seed, mixup, cutmix, switch):
    """Soft labels are a convex combination of the pair's one-hots: rows
    sum to 1, lie in [0, 1], and are supported only on the two classes
    that were mixed."""
    import jax, jax.numpy as jnp
    from repro.data import augment_batch
    if mixup == 0.0 and cutmix == 0.0:
        return  # mixing disabled — covered by the invariance test
    acfg = _aug_cfg(mixup_alpha=mixup, cutmix_alpha=cutmix,
                    switch_prob=switch, mix_prob=1.0, crop_pad=0,
                    flip=False)
    key = jax.random.PRNGKey(seed)
    images = jax.random.normal(jax.random.fold_in(key, 0), (8, 32, 32, 3))
    labels = jax.random.randint(jax.random.fold_in(key, 1), (8,), 0, 10)
    out = augment_batch(jax.random.fold_in(key, 2),
                        {"images": images, "labels": labels}, acfg)
    soft = np.asarray(out["labels"], np.float64)
    assert soft.shape == (8, 10)
    np.testing.assert_allclose(soft.sum(-1), 1.0, atol=1e-5)
    assert (soft >= -1e-6).all() and (soft <= 1.0 + 1e-6).all()
    # support: at most two classes per row; whenever the row is a true
    # two-class mixture, the original label is one of them (a single
    # nonzero class is either the unmixed label or the partner at lam~0)
    for row, lab in zip(soft, np.asarray(labels)):
        nz = np.flatnonzero(row > 1e-6)
        assert len(nz) <= 2, (row, nz)
        if len(nz) == 2:
            assert lab in nz, (row, lab, nz)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2 ** 16), pad=st.sampled_from([0, 2, 4]))
def test_flip_crop_label_invariance(seed, pad):
    """Geometric augmentations never touch labels: with mixing disabled
    the labels pass through hard and bit-identical, and image shapes are
    preserved."""
    import jax
    from repro.data import augment_batch
    acfg = _aug_cfg(mixup_alpha=0.0, cutmix_alpha=0.0, crop_pad=pad)
    key = jax.random.PRNGKey(seed)
    images = jax.random.normal(jax.random.fold_in(key, 0), (6, 32, 32, 3))
    labels = jax.random.randint(jax.random.fold_in(key, 1), (6,), 0, 10)
    out = augment_batch(jax.random.fold_in(key, 2),
                        {"images": images, "labels": labels}, acfg)
    assert out["images"].shape == images.shape
    assert out["labels"].dtype == labels.dtype
    np.testing.assert_array_equal(np.asarray(out["labels"]),
                                  np.asarray(labels))
    # crop with pad=0 and no mixing leaves pixel content drawn from the
    # original image (flip is a permutation of columns)
    if pad == 0:
        a = np.sort(np.asarray(out["images"]), axis=2)
        b = np.sort(np.asarray(images), axis=2)
        np.testing.assert_allclose(a, b, atol=1e-6)
