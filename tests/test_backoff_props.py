"""Backoff-policy properties (hypothesis when available, plus plain
deterministic coverage that always runs): the retry schedule shared by
checkpoint IO, the data prefetcher, and the supervisor must be
monotone-capped, jitter-bounded, attempt-exact, and seed-deterministic —
a wrong schedule either hammers a failing disk or sleeps forever."""
import math

import pytest

from repro.resilience.backoff import BackoffPolicy, TransientError

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                 # not in this container; present in CI
    HAS_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(not HAS_HYPOTHESIS,
                                      reason="hypothesis not installed")


# ---------------------------------------------------------------------------
# deterministic coverage (always runs, container and CI alike)
# ---------------------------------------------------------------------------

def test_raw_delays_monotone_then_capped():
    p = BackoffPolicy(max_attempts=8, base_delay=0.1, multiplier=2.0,
                      max_delay=1.0, jitter=0.0)
    raws = [p.raw_delay(a) for a in range(7)]
    assert raws == sorted(raws)
    assert raws[0] == pytest.approx(0.1)
    assert raws[-1] == 1.0                      # hit the cap
    assert all(r <= 1.0 for r in raws)


def test_delays_are_seed_deterministic():
    p = BackoffPolicy(max_attempts=6, jitter=0.5)
    assert list(p.delays(seed=7)) == list(p.delays(seed=7))
    assert list(p.delays(seed=7)) != list(p.delays(seed=8))


def test_retry_attempt_count_and_success():
    p = BackoffPolicy(max_attempts=4, base_delay=0.01, max_delay=0.01)
    calls = {"n": 0}
    slept = []

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise TransientError("blip")
        return "ok"

    assert p.retry(flaky, seed=0, sleep=slept.append) == "ok"
    assert calls["n"] == 3 and len(slept) == 2


def test_retry_exhausts_then_raises_last_error():
    p = BackoffPolicy(max_attempts=3, base_delay=0.01, max_delay=0.01)
    calls = {"n": 0}

    def always():
        calls["n"] += 1
        raise TransientError(f"blip {calls['n']}")

    with pytest.raises(TransientError, match="blip 3"):
        p.retry(always, seed=0, sleep=lambda d: None)
    assert calls["n"] == 3                      # exactly max_attempts


def test_retry_nonretryable_propagates_immediately():
    p = BackoffPolicy(max_attempts=5)
    calls = {"n": 0}

    def typo():
        calls["n"] += 1
        raise ValueError("not transient")

    with pytest.raises(ValueError):
        p.retry(typo, retryable=(OSError,), sleep=lambda d: None)
    assert calls["n"] == 1


def test_policy_validation():
    with pytest.raises(ValueError):
        BackoffPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        BackoffPolicy(base_delay=-1.0)
    with pytest.raises(ValueError):
        BackoffPolicy(jitter=1.5)


# ---------------------------------------------------------------------------
# property coverage (CI installs hypothesis; skipped where absent — the
# deterministic tests above still run either way)
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    policies = st.builds(
        BackoffPolicy,
        max_attempts=st.integers(1, 16),
        base_delay=st.floats(1e-3, 1.0),
        multiplier=st.floats(1.0, 4.0),
        max_delay=st.floats(1.0, 60.0),
        jitter=st.floats(0.0, 1.0))

    @needs_hypothesis
    @settings(max_examples=100, deadline=None)
    @given(policy=policies)
    def test_prop_raw_delays_monotone_and_capped(policy):
        raws = [policy.raw_delay(a) for a in range(policy.max_attempts)]
        assert all(b >= a for a, b in zip(raws, raws[1:]))
        assert all(0 <= r <= policy.max_delay for r in raws)

    @needs_hypothesis
    @settings(max_examples=100, deadline=None)
    @given(policy=policies, seed=st.integers(0, 2 ** 32 - 1))
    def test_prop_jittered_delays_within_bounds(policy, seed):
        """Every jittered delay stays inside raw*(1 +- jitter) and is
        never negative — the supervisor must not sleep for hours (or
        for -3s)."""
        for attempt, d in enumerate(policy.delays(seed)):
            raw = policy.raw_delay(attempt)
            lo, hi = raw * (1 - policy.jitter), raw * (1 + policy.jitter)
            assert lo - 1e-9 <= d <= hi + 1e-9
            assert d >= 0 and math.isfinite(d)

    @needs_hypothesis
    @settings(max_examples=50, deadline=None)
    @given(policy=policies, seed=st.integers(0, 2 ** 32 - 1))
    def test_prop_delay_stream_seed_deterministic(policy, seed):
        assert list(policy.delays(seed)) == list(policy.delays(seed))
        assert len(list(policy.delays(seed))) == policy.max_attempts - 1

    @needs_hypothesis
    @settings(max_examples=50, deadline=None)
    @given(policy=policies, fail_n=st.integers(0, 20),
           seed=st.integers(0, 2 ** 32 - 1))
    def test_prop_retry_call_counts(policy, fail_n, seed):
        """fn is called min(fail_n+1, max_attempts) times: success stops
        the loop, exhaustion re-raises the final error."""
        calls = {"n": 0}

        def fn():
            calls["n"] += 1
            if calls["n"] <= fail_n:
                raise TransientError("planned")
            return calls["n"]

        if fail_n >= policy.max_attempts:
            with pytest.raises(TransientError):
                policy.retry(fn, seed=seed, sleep=lambda d: None)
            assert calls["n"] == policy.max_attempts
        else:
            assert policy.retry(fn, seed=seed, sleep=lambda d: None) \
                == fail_n + 1
            assert calls["n"] == fail_n + 1
