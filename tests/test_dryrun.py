"""Dry-run integration: one (arch x shape) pair must lower+compile on the
production mesh in a subprocess (512 placeholder devices) and emit sane
roofline numbers. The full 10x4 matrix runs via
`python -m repro.launch.dryrun --all` (results/ JSONLs)."""
import json
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("glm4-9b", "decode_32k"),
    ("zamba2-2.7b", "train_4k"),
])
def test_dryrun_pair(tmp_path, arch, shape):
    out = tmp_path / "rec.jsonl"
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--out", str(out)],
        capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    rec = json.loads(out.read_text().splitlines()[-1])
    assert rec["status"] == "ok"
    assert rec["chips"] == 256
    rl = rec["roofline"]
    assert rl["compute_s"] >= 0 and rl["memory_s"] > 0
    assert rl["dominant"] in ("compute_s", "memory_s", "collective_s")
    # FLOPs accounting sanity: useful fraction must be <= ~1 (analyzer
    # counts at least the model matmuls)
    assert rl["useful_flops_frac"] < 1.5


@pytest.mark.slow
def test_dryrun_multipod_pair(tmp_path):
    out = tmp_path / "rec.jsonl"
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "granite-moe-3b-a800m", "--shape", "train_4k", "--multi-pod",
         "--out", str(out)],
        capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    rec = json.loads(out.read_text().splitlines()[-1])
    assert rec["status"] == "ok" and rec["chips"] == 512
