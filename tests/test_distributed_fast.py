"""Fast-lane distributed-invariant tests: tiny-shape (2-layer smoke
configs, 4 host devices) variants of the @slow integration invariants in
test_engine_distributed.py, cheap enough for CI's every-push fast job —
DP world-size invariance, ZeRO 0/1/3 equivalence, and pp=2 vs dp-only
loss-trajectory parity. The parallelism-correctness contract is enforced
on every push, not just nightly."""
from conftest import run_subprocess

_COMMON = r"""
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config, EngineConfig
from repro.core.engine import DistributedEngine
from repro.launch.specs import concrete_batch

def run_steps(arch, mesh_shape, zero=0, steps=2, accum=2, pipe=1):
    if pipe > 1:
        mesh = jax.make_mesh(mesh_shape + (pipe,), ("data", "model", "pipe"))
    else:
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    cfg = get_smoke_config(arch).replace(dtype="float32")
    ecfg = EngineConfig(train_batch_size=8, gradient_accumulation_steps=accum,
                        zero_stage=zero, lr=1e-3, total_steps=10,
                        warmup_steps=1, pipeline_stages=pipe)
    eng = DistributedEngine(cfg, ecfg, mesh)
    state = eng.init_state(seed=0)
    step = eng.jit_train_step(donate=False)
    losses = []
    with mesh:
        for i in range(steps):
            batch = concrete_batch(cfg, 8, 16, seed=i)
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
    return losses
"""


def test_dp_world_size_invariance_fast():
    """Same global batch -> same loss trajectory on 1 vs 4 dp devices."""
    out = run_subprocess(_COMMON + r"""
l1 = run_steps("vit-b16", (1, 1))
l4 = run_steps("vit-b16", (4, 1))
for a, b in zip(l1, l4):
    assert abs(a - b) < 2e-4, (l1, l4)
print("OK", l1)
""", devices=4, timeout=900)
    assert "OK" in out


def test_zero_stage_equivalence_fast():
    """ZeRO 0/1/3 change sharding, not math (dp2 x tp2)."""
    out = run_subprocess(_COMMON + r"""
base = run_steps("qwen2.5-14b", (2, 2))
for z in (1, 3):
    lz = run_steps("qwen2.5-14b", (2, 2), zero=z)
    for a, b in zip(base, lz):
        assert abs(a - b) < 3e-4, (z, base, lz)
print("OK", base)
""", devices=4, timeout=900)
    assert "OK" in out


def test_pp2_vs_dp_parity_fast():
    """pp=2 (dp2 x pipe2) reproduces the dp-only trajectory — the 1F1B
    pipeline is a schedule change, not a math change."""
    out = run_subprocess(_COMMON + r"""
base = run_steps("vit-b16", (4, 1))
lp = run_steps("vit-b16", (2, 1), pipe=2)
for a, b in zip(base, lp):
    assert abs(a - b) < 3e-4, (base, lp)
print("OK", base)
""", devices=4, timeout=900)
    assert "OK" in out


# ---------------------------------------------------------------------------
# elastic checkpointing (repro.checkpoint): shard-local save + cross-layout
# restore + resume parity, in the fast lane
# ---------------------------------------------------------------------------

_CKPT = r"""
import json, os, tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config, EngineConfig
from repro.core.engine import DistributedEngine
from repro.checkpoint import checkpoint_size_report
from repro.launch.specs import concrete_batch

CFG = get_smoke_config("vit-b16").replace(dtype="float32")

def make_engine(zero=0, pipe=1):
    if pipe > 1:
        mesh = jax.make_mesh((4 // pipe, pipe, 1), ("data", "pipe", "model"))
    else:
        mesh = jax.make_mesh((4, 1), ("data", "model"))
    ecfg = EngineConfig(train_batch_size=8, gradient_accumulation_steps=2,
                        zero_stage=zero, lr=1e-3, total_steps=10,
                        warmup_steps=1, pipeline_stages=pipe)
    return DistributedEngine(CFG, ecfg, mesh)

def run(eng, state, lo, hi):
    step = eng.jit_train_step(donate=False)
    losses = []
    with eng.mesh:
        for i in range(lo, hi):
            state, m = step(state, concrete_batch(CFG, 8, 16, seed=i))
            losses.append(float(m["loss"]))
    return state, losses

def assert_bitwise(a, b):
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree_util.tree_flatten_with_path(b)[0]
    for (pa, xa), (_, xb) in zip(fa, fb):
        assert np.array_equal(np.asarray(jax.device_get(xa)),
                              np.asarray(jax.device_get(xb))), pa
"""


def test_elastic_restore_from_zero3_fast():
    """Save under dp=4 ZeRO-3; restore into dp2 x pp2 AND into dp4 DDP:
    bitwise param/opt equality, then 3 resumed steps match the
    uninterrupted source-layout trajectory within 1e-5. The size report
    proves the save was shard-local (saved bytes == logical bytes — no
    hidden all-gather, no replica written twice — and ZeRO-3 spreads the
    bytes over all 4 devices)."""
    out = run_subprocess(_CKPT + r"""
src = make_engine(zero=3)
s3, _ = run(src, src.init_state(seed=0), 0, 3)
d = tempfile.mkdtemp()
src.save_state(d, s3)

rep = checkpoint_size_report(d, 3)
assert rep["saved_bytes"] == rep["logical_bytes"], rep
shard_bytes = sum(v for k, v in rep["file_bytes"].items()
                  if k.endswith(".npz"))
assert shard_bytes <= rep["saved_bytes"] * 1.05 + 65536, rep
per_dev = rep["per_device_bytes"]
assert len(per_dev) == 4, per_dev
assert max(per_dev.values()) < 0.5 * rep["saved_bytes"], per_dev

# the manifest records the ZeRO-3 dp sharding the leaves were saved under
man = json.load(open(os.path.join(d, "step_00000003", "manifest.json")))
specs = [m["spec"] for k, m in man["leaves"].items()
         if k.startswith("params/stack/")]
assert any(s and "data" in str(s) for s in specs), specs[:4]

_, ref = run(src, s3, 3, 6)                # uninterrupted continuation
for eng2 in (make_engine(pipe=2), make_engine(zero=0)):
    s2 = eng2.restore_state(d)
    assert int(s2.step) == 3
    assert_bitwise(s3.params, s2.params)
    assert_bitwise(s3.opt_state, s2.opt_state)
    _, res = run(eng2, s2, 3, 6)
    for a, b in zip(ref, res):
        assert abs(a - b) < 1e-5, (ref, res)
print("OK", ref)
""", devices=4, timeout=900)
    assert "OK" in out


def test_elastic_restore_from_pp2_fast():
    """Save under pp=2 (stacked-layer L axis sharded over `pipe`); restore
    into dp-only ZeRO-1 — the pipe-sharded stack reassembles into plain dp
    layouts and the trajectory continues within 1e-5."""
    out = run_subprocess(_CKPT + r"""
src = make_engine(pipe=2)
s3, _ = run(src, src.init_state(seed=0), 0, 3)
d = tempfile.mkdtemp()
src.save_state(d, s3)
man = json.load(open(os.path.join(d, "step_00000003", "manifest.json")))
specs = [m["spec"] for k, m in man["leaves"].items()
         if k.startswith("params/stack/")]
assert any(s and "pipe" in str(s) for s in specs), specs[:4]

_, ref = run(src, s3, 3, 6)
eng2 = make_engine(zero=1)
s2 = eng2.restore_state(d)
assert_bitwise(s3.params, s2.params)
assert_bitwise(s3.opt_state, s2.opt_state)
_, res = run(eng2, s2, 3, 6)
for a, b in zip(ref, res):
    assert abs(a - b) < 1e-5, (ref, res)
print("OK", ref)
""", devices=4, timeout=900)
    assert "OK" in out


# ---------------------------------------------------------------------------
# sharded evaluation (core/engine.py evaluate): layout-invariance of the
# integer metric counts + augmented-training resume parity
# ---------------------------------------------------------------------------

_EVAL = r"""
import jax, numpy as np
from repro.configs import get_smoke_config, EngineConfig
from repro.core.engine import DistributedEngine
from repro.data import AugmentConfig, CIFARSource, DataPipeline

CFG = get_smoke_config("vit-b16").replace(dtype="float32")
EVAL_SIZE = 52      # 52 % 8 != 0 -> the final eval batch is mask-padded

def source():
    return CIFARSource("cifar10", seed=3, eval_size=EVAL_SIZE)

def make_engine(dp, pipe=1, zero=0, aug=None):
    if pipe > 1:
        mesh = jax.make_mesh((dp, pipe, 1), ("data", "pipe", "model"))
    else:
        mesh = jax.make_mesh((dp, 1), ("data", "model"))
    ecfg = EngineConfig(train_batch_size=8, gradient_accumulation_steps=2,
                        zero_stage=zero, lr=1e-3, total_steps=10,
                        warmup_steps=1, pipeline_stages=pipe)
    # preproc: the source ships uint8 — the jitted steps normalize/upsample
    return DistributedEngine(CFG, ecfg, mesh, aug=aug,
                             preproc=source().preproc)
"""


def test_eval_counts_layout_invariant_fast():
    """Top-1/top-5 correct counts over a fixed procedural CIFAR split are
    *bitwise-identical integers* across dp1, dp4, and dp2 x pp2 — the
    integer all-reduce makes eval accuracy exactly layout-independent —
    including the mask-padded non-divisible final batch (52 = 6 x 8 + 4).
    The fp32 NLL sum only agrees to reduction-order tolerance."""
    out = run_subprocess(_EVAL + r"""
src = source()
assert src.num_eval_batches(8) * 8 > src.eval_size   # padding exercised

results = []
for dp, pp in ((1, 1), (4, 1), (2, 2)):
    eng = make_engine(dp, pipe=pp)
    state = eng.init_state(seed=0)
    results.append(eng.evaluate(state, src.eval_batches(8)))

base = results[0]
assert base["eval_count"] == EVAL_SIZE, base            # mask excluded pads
assert 0 < base["eval_top5_count"] <= EVAL_SIZE, base
assert base["eval_top1_count"] <= base["eval_top5_count"], base
for res in results[1:]:
    for k in ("eval_top1_count", "eval_top5_count", "eval_count"):
        assert res[k] == base[k], (k, results)          # exact ints
    assert abs(res["eval_loss"] - base["eval_loss"]) < 1e-5, results
print("OK", base)
""", devices=4, timeout=900)
    assert "OK" in out


def test_augmented_resume_replays_stream_fast():
    """Interrupt an *augmented* run (crop/flip/Mixup/CutMix keyed on
    fold_in(state.rng, step)), save, restore into a DIFFERENT layout:
    the resumed run replays the exact augmentation stream — per-step loss
    parity <= 1e-5 against the uninterrupted run — and the final eval
    metrics agree (counts exactly, loss to 1e-5). A second resume into a
    dp2 x pp2 layout checks the staged 1F1B path threads the SAME
    per-microbatch rng streams (parity within the pp-vs-dp 3e-4
    reduction-order contract — a missed augmentation replay would drift
    at the 1e-2 scale)."""
    out = run_subprocess(_EVAL + r"""
import tempfile
AUG = AugmentConfig(num_classes=10)

def run(eng, state, pipe, lo, hi):
    step = eng.jit_train_step(donate=False)
    losses = []
    with eng.mesh:
        for i in range(lo, hi):
            e, ix = int(state.epoch), int(state.batch_index)
            b = pipe.device_put(pipe.batch_at(e, ix))
            state, m = step(state, b)
            state = state.replace(
                epoch=jax.numpy.int32(pipe.next_cursor(e, ix)[0]),
                batch_index=jax.numpy.int32(pipe.next_cursor(e, ix)[1]))
            losses.append(float(m["loss"]))
    return state, losses

def data():
    return DataPipeline(kind="image", global_batch=8, seed=3,
                        source=source())

ref_eng = make_engine(4, aug=AUG)
s, ref = run(ref_eng, ref_eng.init_state(seed=0), data(), 0, 5)
ref_eval = ref_eng.evaluate(s, source().eval_batches(8))

eng1 = make_engine(4, aug=AUG)
s1, head = run(eng1, eng1.init_state(seed=0), data(), 0, 2)
d = tempfile.mkdtemp()
eng1.save_state(d, s1)

eng2 = make_engine(2, zero=1, aug=AUG)      # resume in a different layout
s2 = eng2.restore_state(d)
assert (int(s2.epoch), int(s2.batch_index)) == (int(s1.epoch),
                                                int(s1.batch_index))
s2, tail = run(eng2, s2, data(), 2, 5)
got = head + tail
for a, b in zip(ref, got):
    assert abs(a - b) < 1e-5, (ref, got)
res_eval = eng2.evaluate(s2, source().eval_batches(8))
for k in ("eval_top1_count", "eval_top5_count", "eval_count"):
    assert res_eval[k] == ref_eval[k], (ref_eval, res_eval)
assert abs(res_eval["eval_loss"] - ref_eval["eval_loss"]) < 1e-5

# dp2 x pp2 resume: per-microbatch aug rngs thread through the staged
# 1F1B schedule (pp reduction order admits 3e-4; a missed augmentation
# replay would miss by ~1e-2)
eng3 = make_engine(2, pipe=2, aug=AUG)
s3 = eng3.restore_state(d)
s3, tail_pp = run(eng3, s3, data(), 2, 5)
for a, b in zip(ref[2:], tail_pp):
    assert abs(a - b) < 3e-4, (ref, head + tail_pp)
print("OK", ref, ref_eval["eval_top1_count"])
""", devices=4, timeout=900)
    assert "OK" in out
