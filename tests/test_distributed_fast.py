"""Fast-lane distributed-invariant tests: tiny-shape (2-layer smoke
configs, 4 host devices) variants of the @slow integration invariants in
test_engine_distributed.py, cheap enough for CI's every-push fast job —
DP world-size invariance, ZeRO 0/1/3 equivalence, and pp=2 vs dp-only
loss-trajectory parity. The parallelism-correctness contract is enforced
on every push, not just nightly."""
from conftest import run_subprocess

_COMMON = r"""
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config, EngineConfig
from repro.core.engine import DistributedEngine
from repro.launch.specs import concrete_batch

def run_steps(arch, mesh_shape, zero=0, steps=2, accum=2, pipe=1):
    if pipe > 1:
        mesh = jax.make_mesh(mesh_shape + (pipe,), ("data", "model", "pipe"))
    else:
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    cfg = get_smoke_config(arch).replace(dtype="float32")
    ecfg = EngineConfig(train_batch_size=8, gradient_accumulation_steps=accum,
                        zero_stage=zero, lr=1e-3, total_steps=10,
                        warmup_steps=1, pipeline_stages=pipe)
    eng = DistributedEngine(cfg, ecfg, mesh)
    params, opt = eng.init(seed=0)
    step = eng.jit_train_step(donate=False)
    losses = []
    with mesh:
        for i in range(steps):
            batch = concrete_batch(cfg, 8, 16, seed=i)
            params, opt, m = step(params, opt, batch, jnp.int32(i))
            losses.append(float(m["loss"]))
    return losses
"""


def test_dp_world_size_invariance_fast():
    """Same global batch -> same loss trajectory on 1 vs 4 dp devices."""
    out = run_subprocess(_COMMON + r"""
l1 = run_steps("vit-b16", (1, 1))
l4 = run_steps("vit-b16", (4, 1))
for a, b in zip(l1, l4):
    assert abs(a - b) < 2e-4, (l1, l4)
print("OK", l1)
""", devices=4, timeout=900)
    assert "OK" in out


def test_zero_stage_equivalence_fast():
    """ZeRO 0/1/3 change sharding, not math (dp2 x tp2)."""
    out = run_subprocess(_COMMON + r"""
base = run_steps("qwen2.5-14b", (2, 2))
for z in (1, 3):
    lz = run_steps("qwen2.5-14b", (2, 2), zero=z)
    for a, b in zip(base, lz):
        assert abs(a - b) < 3e-4, (z, base, lz)
print("OK", base)
""", devices=4, timeout=900)
    assert "OK" in out


def test_pp2_vs_dp_parity_fast():
    """pp=2 (dp2 x pipe2) reproduces the dp-only trajectory — the 1F1B
    pipeline is a schedule change, not a math change."""
    out = run_subprocess(_COMMON + r"""
base = run_steps("vit-b16", (4, 1))
lp = run_steps("vit-b16", (2, 1), pipe=2)
for a, b in zip(base, lp):
    assert abs(a - b) < 3e-4, (base, lp)
print("OK", base)
""", devices=4, timeout=900)
    assert "OK" in out
