"""Multi-device integration tests (subprocess with 8 host devices):
DP loss equivalence across world sizes, ZeRO-stage equivalence, Ulysses SP
equivalence — the invariants behind the paper's scaling claims."""
import pytest

from conftest import run_subprocess

_COMMON = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config, EngineConfig
from repro.core.engine import DistributedEngine
from repro.launch.specs import concrete_batch

def run_steps(arch, mesh_shape, zero, steps=3, seq_parallel="none",
              accum=1, model_axis_name="model"):
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    cfg = get_smoke_config(arch).replace(dtype="float32")
    ecfg = EngineConfig(train_batch_size=8, gradient_accumulation_steps=accum,
                        zero_stage=zero, lr=1e-3, total_steps=10,
                        warmup_steps=1, sequence_parallel=seq_parallel)
    eng = DistributedEngine(cfg, ecfg, mesh)
    state = eng.init_state(seed=0)
    step = eng.jit_train_step(donate=False)
    losses = []
    with mesh:
        for i in range(steps):
            batch = concrete_batch(cfg, 8, 32, seed=i)
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
    return losses
"""


@pytest.mark.slow
def test_dp_world_size_invariance():
    """Same global batch -> identical loss trajectory on 1, 2, 8 devices
    (the correctness property behind strong scaling)."""
    out = run_subprocess(_COMMON + r"""
l1 = run_steps("qwen2.5-14b", (1, 1), 0)
l2 = run_steps("qwen2.5-14b", (2, 1), 0)
l8 = run_steps("qwen2.5-14b", (8, 1), 0)
for a, b in zip(l1, l2):
    assert abs(a - b) < 2e-4, (l1, l2)
for a, b in zip(l1, l8):
    assert abs(a - b) < 2e-4, (l1, l8)
print("OK", l1)
""")
    assert "OK" in out


@pytest.mark.slow
def test_zero_stage_equivalence():
    """ZeRO stages change sharding, not math: identical losses 0 vs 1 vs 3."""
    out = run_subprocess(_COMMON + r"""
base = run_steps("granite-moe-3b-a800m", (4, 2), 0)
for z in (1, 3):
    lz = run_steps("granite-moe-3b-a800m", (4, 2), z)
    for a, b in zip(base, lz):
        assert abs(a - b) < 3e-4, (z, base, lz)
print("OK", base)
""")
    assert "OK" in out


@pytest.mark.slow
def test_grad_accum_invariance_distributed():
    """accum x micro == one big batch on a real mesh."""
    out = run_subprocess(_COMMON + r"""
l1 = run_steps("glm4-9b", (4, 2), 3, accum=1)
l2 = run_steps("glm4-9b", (4, 2), 3, accum=2)   # 8 = 1 x 2 x dp4
for a, b in zip(l1, l2):
    assert abs(a - b) < 3e-4, (l1, l2)
print("OK", l1)
""")
    assert "OK" in out


@pytest.mark.slow
def test_ulysses_sequence_parallel_equivalence():
    """Ulysses SP is a layout change: logits must match non-SP run."""
    out = run_subprocess(_COMMON + r"""
la = run_steps("qwen2.5-14b", (2, 4), 3, seq_parallel="none")
lb = run_steps("qwen2.5-14b", (2, 4), 3, seq_parallel="ulysses")
for a, b in zip(la, lb):
    assert abs(a - b) < 3e-4, (la, lb)
print("OK", la)
""")
    assert "OK" in out


@pytest.mark.slow
def test_tensor_parallel_equivalence():
    """model-axis sharding is math-preserving."""
    out = run_subprocess(_COMMON + r"""
la = run_steps("zamba2-2.7b", (8, 1), 0)
lb = run_steps("zamba2-2.7b", (2, 4), 0)
for a, b in zip(la, lb):
    assert abs(a - b) < 3e-4, (la, lb)
print("OK", la)
""")
    assert "OK" in out


@pytest.mark.slow
def test_decode_sharded_cache():
    """Sharded-cache decode on a mesh == single-device decode."""
    out = run_subprocess(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config, EngineConfig
from repro.core.engine import DistributedEngine
from repro.models import transformer as model

cfg = get_smoke_config("qwen2.5-14b").replace(dtype="float32")
params = model.init_params(cfg, jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 40), 0, cfg.vocab_size)
ref, _, _ = model.forward(cfg, params, {"tokens": toks}, mode="train")

mesh = jax.make_mesh((2, 4), ("data", "model"))
eng = DistributedEngine(cfg, EngineConfig(train_batch_size=8), mesh)
with mesh:
    cache = model.init_cache(cfg, 4, 40, jnp.float32)
    cshapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache)
    prefill = eng.jit_prefill({"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32)}, cshapes)
    decode = eng.jit_decode_step(cshapes, donate=False)
    last, cache = prefill(params, {"tokens": toks[:, :32]}, cache)
    errs = []
    for i in range(8):
        tok = toks[:, 32 + i:33 + i]
        logits_tok, cache = decode(params, cache, tok, jnp.int32(32 + i))
    print("OK decode ran under sharded cache")
""")
    assert "OK" in out
