"""Pipeline parallelism (core/pipeline.py): 1F1B schedule invariants,
stage partitioning, single-device semantic parity, and the acceptance
invariant — pp=2/pp=4 on an 8-device dp x pp mesh reproduce the dp-only
loss trajectory for ViT-B/16 and an LM smoke config."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess

from repro.configs import EngineConfig, get_smoke_config
from repro.core import pipeline


# ---------------------------------------------------------------------------
# schedule-level (no devices)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("micro,stages", [(2, 2), (4, 2), (4, 4), (8, 4),
                                          (6, 3), (8, 8)])
def test_1f1b_bubble_count_is_stages_minus_one(micro, stages):
    sched = pipeline.one_f_one_b(micro, stages)
    for s in range(stages):
        assert pipeline.bubble_count(sched, s) == stages - 1, (s, sched[s])


@pytest.mark.parametrize("micro,stages", [(4, 2), (8, 4), (6, 3)])
def test_1f1b_makespan_and_order(micro, stages):
    sched = pipeline.one_f_one_b(micro, stages)
    assert len({len(row) for row in sched}) == 1
    assert len(sched[0]) == 2 * (micro + stages - 1)
    for s in range(stages):
        fwds = [t.micro for t in sched[s] if t and t.kind == "F"]
        bwds = [t.micro for t in sched[s] if t and t.kind == "B"]
        assert fwds == list(range(micro))
        assert bwds == list(range(micro))


@pytest.mark.parametrize("micro,stages", [(4, 2), (8, 4), (8, 8)])
def test_1f1b_in_flight_bound(micro, stages):
    """The defining 1F1B property vs GPipe: stage s never holds more than
    stages - s in-flight microbatch activations."""
    sched = pipeline.one_f_one_b(micro, stages)
    for s in range(stages):
        in_flight = 0
        for task in sched[s]:
            if task is None:
                continue
            in_flight += 1 if task.kind == "F" else -1
            assert in_flight <= stages - s, (s, task)


def test_1f1b_dependency_consistency():
    """Stage s forwards m strictly after stage s-1; backwards strictly after
    stage s+1 (flush semantics — no cross-microbatch reordering hazards)."""
    micro, stages = 6, 3
    sched = pipeline.one_f_one_b(micro, stages)
    tick_of = {}
    for s in range(stages):
        for t, task in enumerate(sched[s]):
            if task:
                tick_of[(s, task.kind, task.micro)] = t
    for m in range(micro):
        for s in range(1, stages):
            assert tick_of[(s, "F", m)] > tick_of[(s - 1, "F", m)]
        for s in range(stages - 1):
            assert tick_of[(s, "B", m)] > tick_of[(s + 1, "B", m)]
        assert tick_of[(stages - 1, "B", m)] > tick_of[(stages - 1, "F", m)]


def test_1f1b_rejects_underfilled_pipe():
    with pytest.raises(ValueError, match="microbatches >= stages"):
        pipeline.one_f_one_b(2, 4)


def test_bubble_fraction():
    assert pipeline.bubble_fraction(4, 1) == 0.0
    assert pipeline.bubble_fraction(4, 4) == pytest.approx(3 / 7)
    assert pipeline.bubble_fraction(16, 4) == pytest.approx(3 / 19)


# ---------------------------------------------------------------------------
# interleaved (Megatron virtual-stage) schedule
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("micro,stages", [(4, 2), (8, 4), (6, 3)])
def test_interleaved_recovers_flat_at_v1(micro, stages):
    assert pipeline.one_f_one_b(micro, stages, interleave=1) == \
        pipeline.one_f_one_b(micro, stages)


@pytest.mark.parametrize("micro,stages,v", [(4, 2, 2), (8, 2, 3), (8, 4, 2),
                                            (12, 4, 2), (6, 3, 4)])
def test_interleaved_hits_megatron_ideal(micro, stages, v):
    """The greedy simulator achieves the interleaved-1F1B ideal exactly:
    makespan 2*v*M + 2*(S-1) unit slots, bubble (S-1)/(v*M + S-1)."""
    sched = pipeline.one_f_one_b(micro, stages, interleave=v)
    assert pipeline.makespan(sched) == 2 * v * micro + 2 * (stages - 1)
    assert pipeline.simulated_bubble_fraction(micro, stages, v) == \
        pytest.approx((stages - 1) / (v * micro + stages - 1))


@pytest.mark.parametrize("micro,stages,v", [(4, 2, 2), (8, 4, 2)])
def test_interleaved_task_coverage_and_placement(micro, stages, v):
    """Every (chunk, micro) F and B runs exactly once, on device
    chunk % S, with B strictly after F."""
    sched = pipeline.one_f_one_b(micro, stages, interleave=v)
    seen = {}
    for d in range(stages):
        for t, task in enumerate(sched[d]):
            if task is None:
                continue
            assert task.chunk % stages == d, (d, task)
            key = (task.kind, task.chunk, task.micro)
            assert key not in seen, key
            seen[key] = t
    for c in range(stages * v):
        for m in range(micro):
            assert seen[("B", c, m)] > seen[("F", c, m)], (c, m)
    assert len(seen) == 2 * stages * v * micro


def test_interleaved_rejects_non_divisible_micro():
    with pytest.raises(ValueError, match="divisible by stages"):
        pipeline.one_f_one_b(6, 4, interleave=2)


def test_simulated_bubble_fraction_matches_flat_formula():
    for m, s in [(4, 2), (8, 4), (16, 4)]:
        assert pipeline.simulated_bubble_fraction(m, s, 1) == \
            pytest.approx(pipeline.bubble_fraction(m, s))


# ---------------------------------------------------------------------------
# partitioning / config validation
# ---------------------------------------------------------------------------

def test_stage_partition_contiguous():
    assert pipeline.stage_partition(12, 4) == [(0, 3), (3, 6), (6, 9),
                                               (9, 12)]
    assert pipeline.stage_partition(2, 1) == [(0, 2)]
    with pytest.raises(ValueError, match="not divisible"):
        pipeline.stage_partition(12, 5)


def test_engine_config_microbatch_ge_stages():
    ecfg = EngineConfig(train_batch_size=16, gradient_accumulation_steps=2,
                        pipeline_stages=4)
    with pytest.raises(ValueError, match="microbatch count >= pipeline"):
        ecfg.validate(2)
    ecfg = EngineConfig(train_batch_size=16, gradient_accumulation_steps=4,
                        pipeline_stages=4)
    ecfg.validate(2)   # 16 = 2 x 4 x 2: fine


def test_engine_config_pp_rejects_ulysses():
    ecfg = EngineConfig(train_batch_size=16, gradient_accumulation_steps=4,
                        pipeline_stages=2, sequence_parallel="ulysses")
    with pytest.raises(ValueError, match="sequence parallelism"):
        ecfg.validate(2)


def test_unsupported_archs_rejected():
    with pytest.raises(ValueError, match="MoE"):
        pipeline.check_supported(get_smoke_config("granite-moe-3b-a800m"))
    with pytest.raises(ValueError, match="block_kind"):
        pipeline.check_supported(get_smoke_config("rwkv6-7b"))
    with pytest.raises(ValueError, match="M-RoPE"):
        # batch-supplied positions would silently reuse microbatch 0's grid
        pipeline.check_supported(get_smoke_config("qwen2-vl-72b"))
    pipeline.check_supported(get_smoke_config("vit-b16"))
    pipeline.check_supported(get_smoke_config("qwen2.5-14b"))


def test_engine_config_pp_accepts_bf16_cast():
    """Per-chunk manual VJPs accumulate cotangents in fp32 regardless of
    compute dtype, so bf16 gather + fp32 master is legal under pp now."""
    ecfg = EngineConfig(train_batch_size=16, gradient_accumulation_steps=4,
                        pipeline_stages=2, cast_params_bf16=True)
    ecfg.validate(2)


def test_engine_config_interleave_validation():
    ok = EngineConfig(train_batch_size=16, gradient_accumulation_steps=4,
                      pipeline_stages=2, pipeline_interleave=2)
    ok.validate(2)
    with pytest.raises(ValueError, match="pipeline_interleave must be"):
        EngineConfig(train_batch_size=16, gradient_accumulation_steps=4,
                     pipeline_stages=2,
                     pipeline_interleave=0).validate(2)
    with pytest.raises(ValueError, match="requires pipeline_stages"):
        EngineConfig(train_batch_size=16, gradient_accumulation_steps=4,
                     pipeline_interleave=2).validate(4)
    with pytest.raises(ValueError, match="divisible by"):
        # M=3 not a multiple of S=2: Megatron grouping needs runs of S
        EngineConfig(train_batch_size=12, gradient_accumulation_steps=3,
                     pipeline_stages=2, pipeline_interleave=2).validate(2)


# ---------------------------------------------------------------------------
# single-device semantics: pipelined loss == reference loss_fn
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["vit-b16", "qwen2.5-14b"])
def test_pipelined_loss_matches_reference(arch, rng):
    from repro.launch.specs import concrete_batch
    from repro.models import transformer as model

    cfg = get_smoke_config(arch).replace(dtype="float32")
    params = model.init_params(cfg, rng)
    batch = concrete_batch(cfg, 8, 32, seed=0)
    ref_loss, ref_metrics = model.loss_fn(cfg, params, batch)

    loss, metrics = jax.jit(
        lambda p, b: pipeline.pipelined_loss(
            cfg, p, b, stages=2, num_micro=4, pipe_axis=None))(params, batch)
    np.testing.assert_allclose(np.asarray(loss), np.asarray(ref_loss),
                               atol=2e-5)
    assert set(metrics) == set(ref_metrics)

    gref = jax.grad(lambda p: model.loss_fn(cfg, p, batch)[0])(params)
    (loss2, _), gpipe = jax.jit(
        lambda p, b: pipeline.pipelined_value_and_grad(
            cfg, p, b, stages=2, num_micro=4, pipe_axis=None))(params, batch)
    np.testing.assert_allclose(np.asarray(loss2), np.asarray(ref_loss),
                               atol=2e-5)
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(gref)[0],
            jax.tree_util.tree_flatten_with_path(gpipe)[0]):
        assert b.dtype == jnp.float32   # fp32 accumulation policy
        err = np.max(np.abs(np.asarray(a) - np.asarray(b)))
        assert err < 1e-4, (jax.tree_util.keystr(path), err)


def test_interleaved_value_and_grad_matches_reference(rng):
    """Single-device semantics of the interleaved executor (S=2, v=2):
    loss and fp32-accumulated grads match the reference model."""
    from repro.launch.specs import concrete_batch
    from repro.models import transformer as model

    cfg = get_smoke_config("vit-b16").replace(dtype="float32", num_layers=4)
    params = model.init_params(cfg, rng)
    batch = concrete_batch(cfg, 8, 32, seed=0)
    ref_loss, _ = model.loss_fn(cfg, params, batch)
    gref = jax.grad(lambda p: model.loss_fn(cfg, p, batch)[0])(params)

    (loss, metrics), grads = jax.jit(
        lambda p, b: pipeline.pipelined_value_and_grad(
            cfg, p, b, stages=2, num_micro=4, interleave=2,
            pipe_axis=None))(params, batch)
    np.testing.assert_allclose(np.asarray(loss), np.asarray(ref_loss),
                               atol=2e-5)
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(gref)[0],
            jax.tree_util.tree_flatten_with_path(grads)[0]):
        err = np.max(np.abs(np.asarray(a) - np.asarray(b)))
        assert err < 1e-4, (jax.tree_util.keystr(path), err)


def test_executed_schedule_matches_simulator_accounting(rng):
    """Acceptance invariant: the executed schedule's per-device F/B/idle
    slot counts and makespan equal the simulator's accounting — for both
    the flat and interleaved schedules (execution is schedule-driven, and
    this pins the coupling)."""
    from repro.launch.specs import concrete_batch
    from repro.models import transformer as model

    cfg = get_smoke_config("vit-b16").replace(dtype="float32", num_layers=4)
    params = model.init_params(cfg, rng)
    batch = concrete_batch(cfg, 4, 32, seed=0)
    for stages, v, micro in [(2, 1, 4), (2, 2, 4), (4, 1, 4)]:
        out = {}
        pipeline.pipelined_value_and_grad(
            cfg, params, batch, stages=stages, num_micro=micro,
            interleave=v, pipe_axis=None, schedule_out=out)
        ref = pipeline.schedule_accounting(micro, stages, v)
        assert out["ticks"] == ref["ticks"], (stages, v)
        assert out["executed"] == {"F": ref["F"], "B": ref["B"],
                                   "idle": ref["idle"]}, (stages, v)


def test_pipelined_rngs_thread_per_microbatch(rng):
    """The staged path delivers microbatch m ITS rng: a microbatch_fn that
    scales images by uniform(rng) changes the loss exactly as the same
    transformation applied microbatch-by-microbatch outside the pipe."""
    from repro.launch.specs import concrete_batch
    from repro.models import transformer as model
    from repro.core.grad_accum import split_microbatches

    cfg = get_smoke_config("vit-b16").replace(dtype="float32", num_layers=2)
    params = model.init_params(cfg, rng)
    batch = concrete_batch(cfg, 8, 32, seed=0)
    rngs = jax.random.split(jax.random.PRNGKey(3), 4)

    def mb_fn(mb, r):
        return dict(mb, images=mb["images"] * jax.random.uniform(r, ()))

    loss, _ = pipeline.pipelined_loss(
        cfg, params, batch, stages=2, num_micro=4, pipe_axis=None,
        rngs=rngs, microbatch_fn=mb_fn)
    mbs = split_microbatches(batch, 4)
    want = np.mean([float(model.loss_fn(
        cfg, params, mb_fn(jax.tree.map(lambda x: x[i], mbs), rngs[i]))[0])
        for i in range(4)])
    np.testing.assert_allclose(float(loss), want, atol=2e-5)


def test_pipelined_loss_rejects_underfilled_pipe(rng):
    from repro.launch.specs import concrete_batch
    from repro.models import transformer as model

    cfg = get_smoke_config("vit-b16").replace(dtype="float32")
    params = model.init_params(cfg, rng)
    batch = concrete_batch(cfg, 8, 32, seed=0)
    with pytest.raises(ValueError, match="microbatches >= stages"):
        pipeline.pipelined_loss(cfg, params, batch, stages=2, num_micro=1,
                                pipe_axis=None)


# ---------------------------------------------------------------------------
# acceptance: 8-device dp x pp meshes reproduce the dp-only trajectory
# ---------------------------------------------------------------------------

_PP_COMMON = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config, EngineConfig
from repro.core.engine import DistributedEngine
from repro.launch.mesh import make_local_mesh
from repro.launch.specs import concrete_batch

def run_steps(arch, pp, zero=0, steps=3, accum=4, layers=4, interleave=1,
              cast_bf16=False):
    mesh = make_local_mesh(model=1, pipe=pp)
    cfg = get_smoke_config(arch).replace(dtype="float32",
                                         num_layers=layers)
    ecfg = EngineConfig(train_batch_size=32, gradient_accumulation_steps=accum,
                        zero_stage=zero, lr=1e-3, total_steps=10,
                        warmup_steps=1, pipeline_stages=pp,
                        pipeline_interleave=interleave,
                        cast_params_bf16=cast_bf16)
    eng = DistributedEngine(cfg, ecfg, mesh)
    state = eng.init_state(seed=0)
    step = eng.jit_train_step(donate=False)
    losses = []
    with mesh:
        for i in range(steps):
            batch = concrete_batch(cfg, 32, 32, seed=i)
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
    return losses
"""


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["vit-b16", "qwen2.5-14b"])
def test_pp_vs_dp_loss_trajectory_8dev(arch):
    """pp=2 and pp=4 on 8 host devices (dp x pp) match dp-only within 3e-4
    over 3 steps — pipeline parallelism is a schedule, not a math change."""
    out = run_subprocess(_PP_COMMON + r"""
base = run_steps("%s", 1)
for pp in (2, 4):
    lp = run_steps("%s", pp)
    for a, b in zip(base, lp):
        assert abs(a - b) < 3e-4, (pp, base, lp)
print("OK", base)
""" % (arch, arch), devices=8)
    assert "OK" in out


@pytest.mark.slow
def test_pp_interleaved_vs_dp_loss_trajectory_8dev():
    """Acceptance: interleaved pp=2 and pp=4 (v=2 virtual chunks per
    device) match the dp-only trajectory within 3e-4 over 3 steps."""
    out = run_subprocess(_PP_COMMON + r"""
base = run_steps("vit-b16", 1, layers=8)
for pp in (2, 4):
    lp = run_steps("vit-b16", pp, layers=8, interleave=2)
    for a, b in zip(base, lp):
        assert abs(a - b) < 3e-4, (pp, base, lp)
print("OK", base)
""", devices=8)
    assert "OK" in out


@pytest.mark.slow
def test_pp_bf16_cast_trajectory_8dev():
    """cast_params_bf16 under pp=2 tracks the dp bf16-cast trajectory —
    the per-chunk VJP path keeps fp32 master grads (looser tol: bf16
    compute)."""
    out = run_subprocess(_PP_COMMON + r"""
base = run_steps("vit-b16", 1, cast_bf16=True)
lp = run_steps("vit-b16", 2, cast_bf16=True)
for a, b in zip(base, lp):
    assert abs(a - b) < 3e-3, (base, lp)
print("OK", base)
""", devices=8)
    assert "OK" in out


@pytest.mark.slow
def test_pp_composes_with_zero3_8dev():
    """ZeRO-3 stage-local shards under pp=2 keep the trajectory."""
    out = run_subprocess(_PP_COMMON + r"""
base = run_steps("vit-b16", 1)
lp = run_steps("vit-b16", 2, zero=3)
for a, b in zip(base, lp):
    assert abs(a - b) < 3e-4, (base, lp)
print("OK", base)
""", devices=8)
    assert "OK" in out


@pytest.mark.slow
def test_pp_train_step_emits_collective_permute():
    """The inter-stage transfer must lower to collective-permute over the
    pipe axis (the ppermute the 1F1B schedule prescribes)."""
    out = run_subprocess(_PP_COMMON + r"""
mesh = make_local_mesh(model=1, pipe=2)
cfg = get_smoke_config("vit-b16").replace(dtype="float32")
ecfg = EngineConfig(train_batch_size=16, gradient_accumulation_steps=4,
                    pipeline_stages=2, total_steps=10, warmup_steps=1)
eng = DistributedEngine(cfg, ecfg, mesh)
batch_shapes = {
    "images": jax.ShapeDtypeStruct((16, cfg.image_size, cfg.image_size, 3),
                                   jnp.float32),
    "labels": jax.ShapeDtypeStruct((16,), jnp.int32)}
hlo = eng.lower_train(batch_shapes).compile().as_text()
assert "collective-permute" in hlo, "no inter-stage collective-permute!"
print("OK collective-permute present")
""", devices=8)
    assert "OK" in out
