"""Sharded streaming source (data/streaming.py): shard writer + manifest,
the LRU-cached global-index gather, and the cursor-determinism contract —
a rebuilt-from-scratch source must replay the identical stream across
shard boundaries (the elastic-resume surface), and the stream must be
invariant to the sharding geometry itself."""
import json
import os

import numpy as np
import pytest

from repro.data import CIFARSource, DataPipeline
from repro.data.streaming import MANIFEST, ShardedSource, write_shards

SEED = 11
TRAIN, EVAL, SHARD = 300, 90, 64


def _source():
    return CIFARSource("cifar10", seed=SEED, train_size=TRAIN,
                       eval_size=EVAL)


@pytest.fixture(scope="module")
def shard_dir(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("shards"))
    write_shards(d, _source(), shard_size=SHARD)
    return d


def test_manifest_and_shard_layout(shard_dir):
    with open(os.path.join(shard_dir, MANIFEST)) as f:
        m = json.load(f)
    assert m["schema"] == "repro-shards/v1"
    tr = m["splits"]["train"]
    assert tr["total"] == TRAIN
    assert tr["sizes"] == [64, 64, 64, 64, 44]      # 300 over 64-shards
    for name in tr["shards"]:
        with np.load(os.path.join(shard_dir, name)) as z:
            assert z["images"].dtype == np.uint8
            assert z["images"].shape[1:] == (32, 32, 3)
            assert z["labels"].dtype == np.int32
    # two writers with the same seed produce byte-identical shards
    ss = ShardedSource(shard_dir, seed=SEED)
    assert ss.train_size == TRAIN and ss.eval_size == EVAL
    assert ss.preproc == _source().preproc


def test_rebuilt_source_replays_identical_stream_across_shards(shard_dir):
    """The elastic-resume contract: a pipeline over a FRESH ShardedSource
    (new process, cold cache) replays byte-identical batches at every
    cursor. global_batch > shard_size, so every batch is guaranteed to
    gather across a shard boundary."""
    def mk():
        return DataPipeline(kind="image", global_batch=128, seed=5,
                            source=ShardedSource(shard_dir, seed=5))
    p1, p2 = mk(), mk()
    assert p1.steps_per_epoch == TRAIN // 128
    for e, i in ((0, 0), (0, 1), (3, 0)):
        a, b = p1.batch_at(e, i), p2.batch_at(e, i)
        np.testing.assert_array_equal(a["images"], b["images"])
        np.testing.assert_array_equal(a["labels"], b["labels"])
    # distinct cursors name distinct batches
    assert not np.array_equal(p1.batch_at(0, 0)["images"],
                              p1.batch_at(0, 1)["images"])


def test_stream_invariant_to_shard_geometry(shard_dir, tmp_path):
    """Re-sharding the same examples at a different shard_size must not
    change the sampled stream: indices are drawn over the GLOBAL range
    and only then resolved through the shard map."""
    other = str(tmp_path / "resharded")
    write_shards(other, _source(), shard_size=37)
    a = ShardedSource(shard_dir).train_batch(64, seed=99)
    b = ShardedSource(other).train_batch(64, seed=99)
    np.testing.assert_array_equal(a["images"], b["images"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    # ...and matches the in-RAM source the shards were written from
    # (same global index draw over the same examples)
    src = _source()
    rng_idx = np.random.default_rng(99).integers(0, TRAIN, (64,))
    rng = np.random.default_rng((SEED, 0x5A4D))
    imgs, labs = src._procedural_examples(rng, TRAIN)
    np.testing.assert_array_equal(a["images"], imgs[rng_idx])
    np.testing.assert_array_equal(a["labels"], labs[rng_idx])


def test_eval_batches_cross_shards_with_padding(shard_dir):
    ss = ShardedSource(shard_dir)
    batches = list(ss.eval_batches(64))
    assert len(batches) == 2 == ss.num_eval_batches(64)
    for b in batches:
        assert b["images"].shape == (64, 32, 32, 3)
        assert b["images"].dtype == np.uint8
    np.testing.assert_array_equal(batches[0]["mask"], np.ones(64))
    assert batches[1]["mask"].sum() == EVAL - 64
    assert np.all(batches[1]["images"][EVAL - 64:] == 0)
    # masked concatenation reproduces the split the writer saw, in order
    got = np.concatenate([b["labels"][b["mask"] > 0] for b in batches])
    np.testing.assert_array_equal(got, _source()._eval_labels)


def test_train_size_bound_and_weak_scaling_pool(shard_dir):
    ss = ShardedSource(shard_dir, train_size=100)
    assert ss.train_size == 100
    b = ss.train_batch(32, seed=7, pool=SHARD)
    # pool=64 == the first shard: every drawn example must live there
    with np.load(os.path.join(shard_dir, "train-00000.npz")) as z:
        first = z["images"]
    for img in b["images"]:
        assert any(np.array_equal(img, a) for a in first)
    with pytest.raises(ValueError, match="out of range"):
        ss.train_batch(4, seed=0, pool=101)


def test_missing_or_bad_manifest_raises(tmp_path):
    with pytest.raises(FileNotFoundError, match="shards.json"):
        ShardedSource(str(tmp_path))
    bad = tmp_path / "bad"
    bad.mkdir()
    (bad / MANIFEST).write_text(json.dumps({"schema": "other/v9"}))
    with pytest.raises(ValueError, match="unsupported shard manifest"):
        ShardedSource(str(bad))


def test_non_multiple_resolution_rejected(shard_dir):
    with pytest.raises(ValueError, match="not an integer multiple"):
        ShardedSource(shard_dir, resolution=48)
