"""Per-architecture smoke tests (REQUIRED deliverable f): reduced variant of
each family — one forward + one real train step on CPU, asserting output
shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, EngineConfig, get_smoke_config
from repro.core.engine import DistributedEngine
from repro.launch.mesh import make_local_mesh
from repro.launch.specs import concrete_batch
from repro.models import transformer as model

B, S = 2, 64


def _batch(cfg):
    return concrete_batch(cfg, B, S, seed=0)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_no_nans(arch, rng):
    cfg = get_smoke_config(arch).replace(dtype="float32")
    params = model.init_params(cfg, rng)
    batch = _batch(cfg)
    logits, _, aux = model.forward(cfg, params, batch, mode="train")
    if cfg.arch_type == "vit":
        assert logits.shape == (B, cfg.num_classes)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert np.isfinite(float(aux["moe_aux"]))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch).replace(dtype="float32")
    mesh = make_local_mesh()
    eng = DistributedEngine(
        cfg, EngineConfig(train_batch_size=B, total_steps=10), mesh)
    state = eng.init_state(seed=0)
    step = eng.jit_train_step(donate=False)
    batch = _batch(cfg)
    with mesh:
        s2, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(s2.step) == int(state.step) + 1
    # params actually changed
    delta = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state.params, s2.params)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS
                                  if a not in ("hubert-xlarge", "vit-b16")])
def test_decode_matches_train_logits(arch, rng):
    """prefill + decode must reproduce train-mode logits (KV/state cache
    correctness) — the serve_step contract."""
    import dataclasses
    cfg = get_smoke_config(arch).replace(dtype="float32", mtp_depth=0)
    if cfg.moe:
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))   # avoid len-dependent drops
    params = model.init_params(cfg, rng)
    extra = 4
    toks = jax.random.randint(rng, (B, S + extra), 0, cfg.vocab_size)
    ref, _, _ = model.forward(cfg, params, {"tokens": toks}, mode="train")
    cache = model.init_cache(cfg, B, S + extra, dtype=jnp.float32)
    pf, cache, _ = model.forward(cfg, params, {"tokens": toks[:, :S]},
                                 mode="prefill", cache=cache)
    np.testing.assert_allclose(np.asarray(pf), np.asarray(ref[:, :S]),
                               atol=5e-4)
    for i in range(extra):
        dl, cache, _ = model.forward(
            cfg, params, {"token": toks[:, S + i:S + i + 1],
                          "index": jnp.int32(S + i)},
            mode="decode", cache=cache)
        np.testing.assert_allclose(np.asarray(dl[:, 0]),
                                   np.asarray(ref[:, S + i]), atol=5e-4)


def test_loss_decreases_vit():
    """A few real steps on learnable synthetic CIFAR: loss must go down."""
    from repro.data import DATASETS, DataPipeline
    cfg = get_smoke_config("vit-b16").replace(dtype="float32")
    mesh = make_local_mesh()
    eng = DistributedEngine(
        cfg, EngineConfig(train_batch_size=16, lr=3e-3, total_steps=30,
                          warmup_steps=3), mesh)
    pipe = DataPipeline(kind="image", global_batch=16,
                        dataset=DATASETS["cifar10"],
                        resolution=cfg.image_size)
    state = eng.init_state(seed=0)
    step = eng.jit_train_step(donate=False)
    losses = []
    with mesh:
        for i, batch in enumerate(pipe.batches()):
            if i >= 30:
                break
            batch = jax.tree.map(jnp.asarray, batch)
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses
