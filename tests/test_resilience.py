"""Fault-tolerance stack: FaultPlan semantics (deterministic schedules,
once-only firing across restarts via the fault log), the engine's
anomaly-guarded step (non-finite loss/grad-norm -> bitwise no-op +
same-batch retry), hardened checkpoint IO (checksums, retry, fallback
restore, retention GC that never deletes the last restorable state), the
auto-resume supervisor, and the end-to-end kill-and-resume chaos run."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointCorruptError,
    gc_checkpoints,
    latest_step,
    latest_valid_step,
    list_steps,
    restore_latest_valid,
    save_checkpoint,
    verify_checkpoint,
)
from repro.configs import EngineConfig, get_smoke_config
from repro.core.engine import DistributedEngine
from repro.launch.mesh import make_local_mesh
from repro.resilience import (
    FaultPlan,
    PermanentFault,
    RESTARTABLE_EXIT,
    TransientError,
    child_argv,
    supervise,
)
from repro.resilience.backoff import BackoffPolicy


# ---------------------------------------------------------------------------
# FaultPlan
# ---------------------------------------------------------------------------

def test_fault_plan_parse_grammar():
    p = FaultPlan.parse("nan_grad@3,ckpt_write@4:transient:5,"
                        "data@2:permanent,sigterm@7")
    kinds = {(f.kind, f.step, f.mode) for f in p.faults}
    assert kinds == {("nan_grad", 3, "transient"),
                     ("ckpt_write", 4, "transient"),
                     ("data", 2, "permanent"),
                     ("preempt", 7, "transient")}   # alias resolved
    assert next(f for f in p.faults if f.kind == "ckpt_write").count == 5


def test_fault_plan_parse_rejects_garbage():
    with pytest.raises(ValueError, match="kind@step"):
        FaultPlan.parse("nan_grad")
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.parse("frobnicate@3")
    with pytest.raises(ValueError, match="@rand"):
        FaultPlan.parse("nan_grad@rand")        # no max_step


def test_fault_plan_rand_and_seeded_are_deterministic():
    a = FaultPlan.parse("nan@rand,preempt@rand", seed=11, max_step=100)
    b = FaultPlan.parse("nan@rand,preempt@rand", seed=11, max_step=100)
    c = FaultPlan.parse("nan@rand,preempt@rand", seed=12, max_step=100)
    steps = lambda p: [f.step for f in p.faults]
    assert steps(a) == steps(b)
    assert steps(a) != steps(c)
    assert steps(FaultPlan.seeded(5, 50)) == steps(FaultPlan.seeded(5, 50))
    assert all(1 <= s < 50 for s in steps(FaultPlan.seeded(5, 50)))


def test_fault_check_transient_resolves_permanent_does_not():
    p = FaultPlan.parse("ckpt_write@3:transient:2,data@4:permanent")
    for _ in range(2):
        with pytest.raises(TransientError):
            p.check("ckpt_write", 3)
    p.check("ckpt_write", 3)                    # resolved after `count`
    for _ in range(3):                          # permanent never resolves
        with pytest.raises(PermanentFault):
            p.check("data", 4)
    p.check("data", 99)                         # wrong step: no-op


def test_poison_batch_fires_once():
    p = FaultPlan.parse("nan_grad@2")
    batch = {"images": np.ones((2, 2), np.float32),
             "labels": np.arange(2, dtype=np.int32)}
    fed = p.poison_batch(batch, 2)
    assert np.isnan(fed["images"]).all()
    assert fed["labels"].dtype == np.int32      # ints untouched
    again = p.poison_batch(batch, 2)            # once-only: clean again
    assert np.isfinite(np.asarray(again["images"])).all()


def test_poison_batch_handles_uint8_image_batches():
    """The uint8 streaming data path ships no float leaf — poisoning
    must still yield a batch the guard can catch: the images leaf
    becomes float32 NaN at the model resolution (device_preprocess
    passes float batches through untouched)."""
    p = FaultPlan.parse("nan_grad@1")
    batch = {"images": np.zeros((2, 16, 16, 3), np.uint8),
             "labels": np.arange(2, dtype=np.int32)}
    fed = p.poison_batch(batch, 1, resolution=32)
    assert fed["images"].dtype == np.float32
    assert fed["images"].shape == (2, 32, 32, 3)
    assert np.isnan(fed["images"]).all()
    assert fed["labels"].dtype == np.int32
    assert batch["images"].dtype == np.uint8    # original untouched


def test_fault_log_marks_fired_faults_consumed(tmp_path):
    """The once-only-across-restarts contract: a relaunched run that
    re-executes the fault step must not replay the fault."""
    log = str(tmp_path / "faults.jsonl")
    p1 = FaultPlan.parse("nan_grad@2,preempt@5", log_path=log)
    p1.poison_batch({"x": np.ones(2, np.float32)}, 2)
    recs = [json.loads(l) for l in open(log)]
    assert [r["kind"] for r in recs] == ["nan_grad"]
    p2 = FaultPlan.parse("nan_grad@2,preempt@5", log_path=log)
    nan = next(f for f in p2.faults if f.kind == "nan_grad")
    pre = next(f for f in p2.faults if f.kind == "preempt")
    assert nan.exhausted and not pre.exhausted
    clean = p2.poison_batch({"x": np.ones(2, np.float32)}, 2)
    assert np.isfinite(clean["x"]).all()


def test_install_shims_are_noops_without_plan():
    from repro.resilience import faults
    assert faults.active() is None
    faults.check("data", 3)                     # no plan: must not raise
    b = {"x": np.ones(1, np.float32)}
    assert faults.poison_batch(b, 3) is b
    with FaultPlan.parse("data@3:permanent") as plan:
        assert faults.active() is plan
        with pytest.raises(PermanentFault):
            faults.check("data", 3)
    assert faults.active() is None              # context-managed uninstall


# ---------------------------------------------------------------------------
# anomaly-guarded engine step
# ---------------------------------------------------------------------------

def _guard_engine(guard=True):
    cfg = get_smoke_config("vit-b16").replace(dtype="float32")
    ecfg = EngineConfig(train_batch_size=4, total_steps=10, warmup_steps=1,
                        guard_anomalies=guard)
    return cfg, DistributedEngine(cfg, ecfg, make_local_mesh())


def _image_batch(cfg, nan=False):
    rng = np.random.default_rng(0)
    img = rng.normal(0, 1, (4, cfg.image_size, cfg.image_size, 3))
    img = img.astype(np.float32) * (float("nan") if nan else 1.0)
    return {"images": img, "labels": np.arange(4, dtype=np.int32) % 10}


def test_guard_skips_nan_step_bitwise_and_retry_advances():
    cfg, eng = _guard_engine()
    state = eng.init_state(seed=0)
    step = eng.jit_train_step(donate=False)
    with eng.mesh:
        s1, m1 = step(state, _image_batch(cfg, nan=True))
        assert int(m1["step_ok"]) == 0
        assert int(s1.step) == int(state.step)  # step did not advance
        for a, b in zip(jax.tree_util.tree_leaves(state.params),
                        jax.tree_util.tree_leaves(s1.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        s2, m2 = step(s1, _image_batch(cfg))    # same-batch retry, clean
        assert int(m2["step_ok"]) == 1
        assert int(s2.step) == int(state.step) + 1
        assert np.isfinite(float(m2["loss"]))


def test_guard_off_has_no_step_ok_metric():
    cfg, eng = _guard_engine(guard=False)
    state = eng.init_state(seed=0)
    step = eng.jit_train_step(donate=False)
    with eng.mesh:
        _, m = step(state, _image_batch(cfg))
        assert "step_ok" not in m


# ---------------------------------------------------------------------------
# hardened checkpoint IO
# ---------------------------------------------------------------------------

def _tree(v):
    return {"w": jnp.full((16, 8), float(v)), "step": jnp.int32(v)}


def _corrupt(ckpt_dir, step):
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    shard = next(n for n in sorted(os.listdir(d))
                 if n.startswith("shards-"))
    with open(os.path.join(d, shard), "r+b") as f:
        f.seek(os.path.getsize(os.path.join(d, shard)) // 2)
        f.write(b"\xde\xad\xbe\xef" * 4)


def test_verify_detects_corruption(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree(1))
    verify_checkpoint(str(tmp_path), 1)         # sound
    _corrupt(str(tmp_path), 1)
    with pytest.raises(CheckpointCorruptError):
        verify_checkpoint(str(tmp_path), 1)


def test_restore_falls_back_past_corrupt_latest(tmp_path):
    for s in (1, 2, 3):
        save_checkpoint(str(tmp_path), s, _tree(s))
    _corrupt(str(tmp_path), 3)
    assert latest_step(str(tmp_path)) == 3      # still *listed*
    assert latest_valid_step(str(tmp_path)) == 2
    tree, step = restore_latest_valid(str(tmp_path), _tree(0))
    assert step == 2
    np.testing.assert_array_equal(np.asarray(tree["w"]),
                                  np.full((16, 8), 2.0))


def test_restore_latest_valid_raises_when_all_corrupt(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree(1))
    _corrupt(str(tmp_path), 1)
    with pytest.raises(FileNotFoundError, match="all failed verification"):
        restore_latest_valid(str(tmp_path), _tree(0))


def test_list_steps_skips_tmp_and_manifestless(tmp_path):
    save_checkpoint(str(tmp_path), 5, _tree(5))
    os.makedirs(tmp_path / "step_00000007.tmp")     # torn staging
    os.makedirs(tmp_path / "step_00000008")         # manifest-less
    (tmp_path / "step_00000008" / "shards-p00.npz").write_bytes(b"junk")
    assert list_steps(str(tmp_path)) == [5]
    assert latest_step(str(tmp_path)) == 5


def test_save_retries_transient_write_faults(tmp_path):
    """An injected transient ckpt_write fault is absorbed by the IO
    retry — the save lands and verifies."""
    retry = BackoffPolicy(max_attempts=4, base_delay=0.01, max_delay=0.01)
    with FaultPlan.parse("ckpt_write@1:transient:2"):
        save_checkpoint(str(tmp_path), 1, _tree(1), retry=retry)
    verify_checkpoint(str(tmp_path), 1)


def test_save_gives_up_on_permanent_write_fault(tmp_path):
    retry = BackoffPolicy(max_attempts=3, base_delay=0.01, max_delay=0.01)
    with FaultPlan.parse("ckpt_write@1:permanent"):
        with pytest.raises(PermanentFault):
            save_checkpoint(str(tmp_path), 1, _tree(1), retry=retry)
    assert list_steps(str(tmp_path)) == []


def test_gc_keeps_last_k(tmp_path):
    for s in range(1, 6):
        save_checkpoint(str(tmp_path), s, _tree(s))
    deleted = gc_checkpoints(str(tmp_path), 2)
    assert deleted == [1, 2, 3]
    assert list_steps(str(tmp_path)) == [4, 5]


def test_gc_never_deletes_last_valid_checkpoint(tmp_path):
    """Retention must not destroy the only restorable state: when every
    step inside the window is corrupt, the newest VALID step survives."""
    for s in (1, 2, 3):
        save_checkpoint(str(tmp_path), s, _tree(s))
    _corrupt(str(tmp_path), 2)
    _corrupt(str(tmp_path), 3)
    deleted = gc_checkpoints(str(tmp_path), 2)
    assert 1 not in deleted
    assert set(list_steps(str(tmp_path))) == {1, 2, 3}  # nothing deletable
    tree, step = restore_latest_valid(str(tmp_path), _tree(0))
    assert step == 1


def test_save_checkpoint_keep_last_k_inline_gc(tmp_path):
    for s in range(1, 5):
        save_checkpoint(str(tmp_path), s, _tree(s), keep_last_k=2)
    assert list_steps(str(tmp_path)) == [3, 4]


# ---------------------------------------------------------------------------
# supervisor
# ---------------------------------------------------------------------------

class _FakeProc:
    def __init__(self, rc):
        self._rc = rc

    def wait(self):
        return self._rc

    def poll(self):
        return self._rc

    def send_signal(self, sig):
        pass


def _fake_popen(rcs, launched):
    it = iter(rcs)

    def popen(cmd):
        launched.append(list(cmd))
        return _FakeProc(next(it))
    return popen


def test_supervise_restarts_until_success():
    launched, slept = [], []
    rc = supervise(["train"], max_restarts=3,
                   backoff=BackoffPolicy(max_attempts=8, base_delay=0.01,
                                         max_delay=0.01),
                   seed=0, sleep=slept.append,
                   popen=_fake_popen([RESTARTABLE_EXIT, 1, 0], launched),
                   log=lambda m: None)
    assert rc == 0
    assert len(launched) == 3 and len(slept) == 2


def test_supervise_exhausts_restart_budget():
    launched = []
    rc = supervise(["train"], max_restarts=2,
                   backoff=BackoffPolicy(max_attempts=8, base_delay=0.01,
                                         max_delay=0.01),
                   seed=0, sleep=lambda d: None,
                   popen=_fake_popen([1, 1, 1, 1], launched),
                   log=lambda m: None)
    assert rc == 1 and len(launched) == 3       # initial + 2 restarts


def test_supervise_zero_restarts_passes_through():
    rc = supervise(["train"], max_restarts=0, sleep=lambda d: None,
                   popen=_fake_popen([RESTARTABLE_EXIT], []),
                   log=lambda m: None)
    assert rc == RESTARTABLE_EXIT


def test_child_argv_strips_supervision_flags_and_adds_resume():
    argv = ["--steps", "6", "--supervise", "--max-restarts", "2",
            "--ckpt-dir", "/tmp/x"]
    cmd = child_argv(argv)
    assert cmd[:3] == [sys.executable, "-m", "repro.launch.train"]
    tail = cmd[3:]
    assert "--supervise" not in tail and "--max-restarts" not in tail
    assert "2" not in tail                      # the flag VALUE went too
    assert tail.count("--resume") == 1
    # idempotent: an already-resuming child argv gains nothing
    assert child_argv(tail).count("--resume") == 1


# ---------------------------------------------------------------------------
# end-to-end: supervised chaos run matches the uninterrupted trajectory
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_supervised_chaos_run_matches_baseline(tmp_path):
    """The acceptance invariant: NaN-grad + corrupt-checkpoint + SIGTERM
    mid-run, under the supervisor, auto-resumes and reproduces the
    uninterrupted run's losses to <= 1e-5 on every step both executed."""
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    common = [sys.executable, "-m", "repro.launch.train", "--arch",
              "vit-b16", "--smoke", "--steps", "6", "--batch", "8",
              "--devices", "2", "--dtype", "float32", "--log-every", "1"]

    base_out = tmp_path / "base.json"
    r = subprocess.run(common + ["--metrics-out", str(base_out)],
                       env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr

    ck = tmp_path / "ck"
    chaos_out = tmp_path / "chaos.json"
    r = subprocess.run(
        common + ["--ckpt-dir", str(ck), "--ckpt-every", "2",
                  "--ckpt-sync", "--keep-last", "3", "--supervise",
                  "--max-restarts", "2", "--inject-faults",
                  "nan_grad@1,ckpt_corrupt@2,preempt@3",
                  "--metrics-out", str(chaos_out)],
        env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "launch attempt 2/3" in r.stdout     # it DID restart
    assert "update skipped" in r.stdout         # the guard DID trip

    base = {m["step"]: m["loss"] for m in json.load(open(base_out))
            if "loss" in m}
    chaos = {m["step"]: m["loss"] for m in json.load(open(chaos_out))
             if "loss" in m}
    common_steps = sorted(set(base) & set(chaos))
    assert common_steps, (base, chaos)
    for s in common_steps:
        assert abs(base[s] - chaos[s]) <= 1e-5, (s, base[s], chaos[s])

    recs = [json.loads(l) for l in open(ck / "faults.jsonl")]
    assert {r["kind"] for r in recs} == {"nan_grad", "ckpt_corrupt",
                                         "preempt"}
