"""1-bit LAMB (paper §V ref [15]): error-feedback compression properties
and convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.optim.onebit import compress_ef, compressed_bytes, \
    make_onebit_optimizer


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_error_feedback_is_lossless_in_sum(seed):
    """q_t + e_t == g_t + e_{t-1} exactly: no gradient mass is lost."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (64,))
    err = 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 1), (64,))
    q, new_err = compress_ef(g, err)
    np.testing.assert_allclose(np.asarray(q + new_err),
                               np.asarray(g + err), atol=1e-6)


def test_compression_is_one_bit():
    g = jax.random.normal(jax.random.PRNGKey(0), (128,))
    q, _ = compress_ef(g, jnp.zeros((128,)))
    vals = np.unique(np.abs(np.asarray(q)))
    assert len(vals) == 1                      # single magnitude
    assert compressed_bytes(128 * 4) == 16.0   # 32x fewer wire bytes


def test_error_accumulates_and_corrects():
    """With EF, the *running sum* of compressed grads tracks the running
    sum of true grads (the signSGD-EF convergence mechanism)."""
    key = jax.random.PRNGKey(1)
    gs = jax.random.normal(key, (50, 16))
    err = jnp.zeros((16,))
    q_sum = jnp.zeros((16,))
    for g in gs:
        q, err = compress_ef(g, err)
        q_sum = q_sum + q
    g_sum = gs.sum(0)
    # residual difference is exactly the final error buffer
    np.testing.assert_allclose(np.asarray(g_sum - q_sum), np.asarray(err),
                               atol=1e-4)


def test_onebit_lamb_converges():
    opt = make_onebit_optimizer("lamb", weight_decay=0.0, grad_clip=0.0)
    w = {"w": jnp.array([3.0, -2.0, 1.5, 0.7])}
    state = opt.init(w)

    def loss(w):
        return jnp.sum(w["w"] ** 2)

    l0 = float(loss(w))
    for _ in range(80):
        g = jax.grad(loss)(w)
        w, state, _ = opt.update(g, state, w, 0.05)
    assert float(loss(w)) < l0 * 0.3


def test_onebit_end_to_end_training():
    """Full model trains with 1-bit adamw (loss decreases)."""
    from repro.configs import get_smoke_config
    from repro.models import transformer as model
    from repro.launch.specs import concrete_batch
    from repro.optim.onebit import make_onebit_optimizer

    cfg = get_smoke_config("chatglm3-6b").replace(dtype="float32")
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    opt = make_onebit_optimizer("adamw", weight_decay=0.0)
    state = opt.init(params)
    batch = concrete_batch(cfg, 4, 32, seed=0)

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(cfg, p, batch)[0])(params)
        params, state, _ = opt.update(grads, state, params, 1e-3)
        return params, state, loss

    losses = []
    for _ in range(12):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.1, losses
