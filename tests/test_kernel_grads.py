"""Gradient parity for the wkv6 and fused-rmsnorm backward kernels:
``jax.grad`` through the Pallas custom VJPs must match ``jax.vjp`` of the
pure-jnp ``ref.py`` oracles (interpret=True executes the backward kernel
bodies on CPU). Covers bf16 inputs, chunk-tail/ragged rows, the structural
no-interpreter-differentiation property, grid-level flash pruning, and the
end-to-end rwkv6-7b + vit-b16 train steps with ``use_pallas`` on vs off."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import rmsnorm as rms_mod
from repro.kernels import wkv6 as wkv_mod
from repro.kernels.flash_attention import grid_cells
from repro.kernels.ops import fused_rmsnorm, wkv6
from repro.kernels.ref import ref_rmsnorm, ref_wkv6

KEY = jax.random.PRNGKey(11)


def _assert_close(got, want, *, rtol, atol, names=None):
    names = names or [str(i) for i in range(len(got))]
    for n, g, r in zip(names, got, want):
        np.testing.assert_allclose(
            np.asarray(g, np.float32), np.asarray(r, np.float32),
            rtol=rtol, atol=atol, err_msg=f"grad {n}")


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------

def _wkv_inputs(b, s, h, p, dtype):
    ks = jax.random.split(KEY, 8)
    r, k, v = (jax.random.normal(ks[i], (b, s, h, p), dtype)
               for i in range(3))
    wlog = (-jnp.exp(jax.random.normal(ks[3], (b, s, h, p)) - 0.5)
            ).astype(dtype)
    u = 0.3 * jax.random.normal(ks[4], (h, p))
    s0 = 0.1 * jax.random.normal(ks[5], (b, h, p, p))
    wo = jax.random.normal(ks[6], (b, s, h, p))        # fixed cotangents
    ws = jax.random.normal(ks[7], (b, h, p, p))
    return (r, k, v, wlog, u, s0), wo, ws


def _wkv_grads(fn, args, wo, ws):
    def loss(*a):
        o, s_end = fn(*a)
        return (jnp.sum(o.astype(jnp.float32) * wo)
                + jnp.sum(s_end.astype(jnp.float32) * ws))
    return jax.grad(loss, argnums=tuple(range(6)))(*args)


WKV_CASES = [
    (1, 64, 2, 32, 16),
    (2, 128, 4, 64, 32),
    (1, 96, 2, 64, 32),
    (2, 57, 3, 32, 16),    # ragged: ops.wkv6 pads the chunk tail
]


@pytest.mark.parametrize("b,s,h,p,chunk", WKV_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_grad_matches_ref(b, s, h, p, chunk, dtype):
    args, wo, ws = _wkv_inputs(b, s, h, p, dtype)
    got = _wkv_grads(
        lambda *a: wkv6(*a, chunk=chunk, interpret=True), args, wo, ws)
    names = ("dr", "dk", "dv", "dwlog", "du", "ds0")
    for g, x in zip(got, args):
        assert g.dtype == x.dtype and g.shape == x.shape
    if dtype == jnp.float32:
        want = _wkv_grads(ref_wkv6, args, wo, ws)
        _assert_close(got, want, rtol=1e-3, atol=1e-3, names=names)
    else:
        # bf16: compare against the fp32 oracle; the error is input-
        # quantization dominated (fp32 accumulation inside the kernel)
        f32_args = tuple(x.astype(jnp.float32) for x in args[:4]) + args[4:]
        want = _wkv_grads(ref_wkv6, f32_args, wo, ws)
        _assert_close(got, want, rtol=0.3, atol=0.3, names=names)


def test_wkv6_grad_strong_decay_finite():
    """The pairwise-decay backward must stay finite under extreme decay
    (the factored e^L / e^-L adjoints would overflow fp32 here)."""
    b, s, h, p = 1, 128, 2, 32
    args, wo, ws = _wkv_inputs(b, s, h, p, jnp.float32)
    args = args[:3] + (jnp.full((b, s, h, p), -8.0),) + args[4:]
    got = _wkv_grads(
        lambda *a: wkv6(*a, chunk=32, interpret=True), args, wo, ws)
    want = _wkv_grads(ref_wkv6, args, wo, ws)
    for g in got:
        assert np.isfinite(np.asarray(g)).all()
    _assert_close(got, want, rtol=1e-3, atol=1e-3)


def test_wkv6_no_interpreter_differentiation():
    """Structural: the wkv6 kernel entry is backed by a custom VJP — grads
    can never fall back to differentiating the forward interpreter."""
    assert isinstance(wkv_mod._wkv, jax.custom_vjp)


# ---------------------------------------------------------------------------
# fused rmsnorm
# ---------------------------------------------------------------------------

RMS_CASES = [
    ((64, 256), 256),
    ((3, 37, 128), 16),     # ragged rows: rows % block_rows != 0
    ((2, 2, 2, 512), 4),
    ((1024, 512), 256),
]


@pytest.mark.parametrize("shape,block_rows", RMS_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_grad_matches_ref(shape, block_rows, dtype):
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], shape, dtype)
    sc = jax.random.normal(ks[1], shape[-1:])
    w = jax.random.normal(ks[2], shape)

    def grads(fn, x):
        return jax.grad(
            lambda x, s: jnp.sum(fn(x, s).astype(jnp.float32) * w),
            argnums=(0, 1))(x, sc)

    got = grads(lambda x, s: fused_rmsnorm(
        x, s, block_rows=block_rows, interpret=True), x)
    assert got[0].dtype == x.dtype and got[1].dtype == sc.dtype
    if dtype == jnp.float32:
        want = grads(ref_rmsnorm, x)
        _assert_close(got, want, rtol=1e-4, atol=1e-4,
                      names=("dx", "dscale"))
    else:
        want = grads(ref_rmsnorm, x.astype(jnp.float32))
        _assert_close(got, want, rtol=6e-2, atol=6e-2,
                      names=("dx", "dscale"))


def test_rmsnorm_rinv_residual_is_fp32():
    """The saved per-row inv-rms residual: fp32, one scalar per row."""
    x = jax.random.normal(KEY, (6, 37, 128), jnp.bfloat16)
    sc = jnp.ones((128,))
    out, rinv = rms_mod.fused_rmsnorm_fwd(x, sc, interpret=True)
    assert out.shape == x.shape and out.dtype == x.dtype
    assert rinv.dtype == jnp.float32 and rinv.shape == (6 * 37,)
    want = 1.0 / np.sqrt(np.mean(
        np.asarray(x, np.float32) ** 2, axis=-1) + 1e-6)
    np.testing.assert_allclose(np.asarray(rinv).reshape(6, 37), want,
                               rtol=1e-2)


def test_rmsnorm_no_interpreter_differentiation():
    assert isinstance(rms_mod._rms, jax.custom_vjp)


# ---------------------------------------------------------------------------
# flash grid-level pruning (index-map DMA pruning)
# ---------------------------------------------------------------------------

def test_flash_grid_pruning_shrinks_launched_grid():
    """The causal grid launches ~half the dense cell count at s=1024 (the
    acceptance bar: skipped K-blocks are never DMA'd, not just predicated
    out), and pruning composes with static windows."""
    live, dense = grid_cells(1024, 1024, causal=True)
    assert dense == 64 and live == 36            # nq*(nq+1)/2 at 128-blocks
    assert live / dense <= 0.6
    wlive, _ = grid_cells(1024, 1024, causal=True, window=128)
    assert wlive < live                          # window prunes further
    assert grid_cells(1024, 1024, causal=False) == (64, 64)
    assert grid_cells(1024, 1024, causal=True, block_skip=False) == (64, 64)


# ---------------------------------------------------------------------------
# end-to-end train-step parity (use_pallas on vs off)
# ---------------------------------------------------------------------------

def _train_step_parity(arch, batch_fn, atol):
    from repro.configs import get_smoke_config
    from repro.models import transformer as model

    cfg0 = get_smoke_config(arch).replace(dtype="float32")
    cfg1 = cfg0.replace(use_pallas=True)
    params = model.init_params(cfg0, KEY)
    batch = batch_fn(cfg0)
    l0 = model.loss_fn(cfg0, params, batch)[0]
    l1 = model.loss_fn(cfg1, params, batch)[0]
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
    g0 = jax.grad(lambda p: model.loss_fn(cfg0, p, batch)[0])(params)
    g1 = jax.grad(lambda p: model.loss_fn(cfg1, p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol)


def test_rwkv6_train_step_use_pallas_grads_match_naive():
    """End-to-end wiring: RWKV6 trains through the wkv6 + fused-rmsnorm
    custom VJPs when use_pallas=True, and its parameter gradients match
    the pure-jnp chunked-scan path."""
    def batch_fn(cfg):
        return {"tokens": jax.random.randint(KEY, (2, 48), 0,
                                             cfg.vocab_size)}
    _train_step_parity("rwkv6-7b", batch_fn, atol=2e-4)


def test_vit_train_step_use_pallas_grads_match_naive():
    """End-to-end wiring: the ViT (the paper's workload) trains through the
    flash VJP — with grid-level pruning live — when use_pallas=True."""
    def batch_fn(cfg):
        ks = jax.random.split(KEY, 2)
        return {
            "images": jax.random.normal(ks[0], (2, cfg.image_size,
                                                cfg.image_size, 3)),
            "labels": jax.random.randint(ks[1], (2,), 0, cfg.num_classes),
        }
    _train_step_parity("vit-b16", batch_fn, atol=2e-4)
