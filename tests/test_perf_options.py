"""Beyond-paper §Perf options must be math-preserving (within dtype tol):
gather-MoE dispatch, bf16-cast-before-gather, d_model embed sharding,
blockwise attention in the full model."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import EngineConfig, get_smoke_config
from repro.core.engine import DistributedEngine
from repro.launch.mesh import make_local_mesh
from repro.launch.specs import concrete_batch
from repro.models import transformer as model

KEY = jax.random.PRNGKey(3)


@pytest.mark.parametrize("arch", ["deepseek-v3-671b", "granite-moe-3b-a800m"])
def test_gather_moe_equals_gshard(arch):
    cfg0 = get_smoke_config(arch).replace(dtype="float32", mtp_depth=0)
    cfg0 = cfg0.replace(moe=dataclasses.replace(cfg0.moe,
                                                capacity_factor=8.0))
    cfg1 = cfg0.replace(moe_impl="gather")
    params = model.init_params(cfg0, KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg0.vocab_size)}
    l0, _, a0 = model.forward(cfg0, params, batch, mode="train")
    l1, _, a1 = model.forward(cfg1, params, batch, mode="train")
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=2e-5)
    np.testing.assert_allclose(float(a0["moe_aux"]), float(a1["moe_aux"]),
                               rtol=1e-5)


def test_gather_moe_grads_match():
    cfg0 = get_smoke_config("granite-moe-3b-a800m").replace(
        dtype="float32", mtp_depth=0)
    cfg0 = cfg0.replace(moe=dataclasses.replace(cfg0.moe,
                                                capacity_factor=8.0))
    cfg1 = cfg0.replace(moe_impl="gather")
    params = model.init_params(cfg0, KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg0.vocab_size)}

    g0 = jax.grad(lambda p: model.loss_fn(cfg0, p, batch)[0])(params)
    g1 = jax.grad(lambda p: model.loss_fn(cfg1, p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


def test_gather_moe_decode():
    cfg = get_smoke_config("granite-moe-3b-a800m").replace(
        dtype="float32", mtp_depth=0, moe_impl="gather")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = model.init_params(cfg, KEY)
    S, extra = 16, 2
    toks = jax.random.randint(KEY, (2, S + extra), 0, cfg.vocab_size)
    ref, _, _ = model.forward(cfg, params, {"tokens": toks}, mode="train")
    cache = model.init_cache(cfg, 2, S + extra, dtype=jnp.float32)
    _, cache, _ = model.forward(cfg, params, {"tokens": toks[:, :S]},
                                mode="prefill", cache=cache)
    for i in range(extra):
        dl, cache, _ = model.forward(
            cfg, params, {"token": toks[:, S + i:S + i + 1],
                          "index": jnp.int32(S + i)},
            mode="decode", cache=cache)
        np.testing.assert_allclose(np.asarray(dl[:, 0]),
                                   np.asarray(ref[:, S + i]), atol=5e-4)


@pytest.mark.parametrize("opt", [
    dict(cast_params_bf16=True),
    dict(embed_sharding="dmodel"),
])
def test_perf_option_training_still_learns(opt):
    cfg = get_smoke_config("qwen2.5-14b").replace(dtype="float32")
    mesh = make_local_mesh()
    eng = DistributedEngine(cfg, EngineConfig(
        train_batch_size=8, lr=3e-3, total_steps=20, warmup_steps=2, **opt),
        mesh)
    state = eng.init_state(seed=0)
    step = eng.jit_train_step(donate=False)
    losses = []
    with mesh:
        for i in range(12):
            batch = concrete_batch(cfg, 8, 32, seed=0)  # fixed batch
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.05, losses
    assert np.isfinite(losses).all()


def test_blockwise_full_model_parity():
    cfg0 = get_smoke_config("gemma3-12b").replace(dtype="float32")
    cfg1 = cfg0.replace(attn_impl="blockwise", attn_block_k=32,
                        attn_block_q=32)
    params = model.init_params(cfg0, KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 64), 0, cfg0.vocab_size)}
    l0, _, _ = model.forward(cfg0, params, batch, mode="train")
    l1, _, _ = model.forward(cfg1, params, batch, mode="train")
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=3e-4)
