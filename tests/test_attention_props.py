"""Additional attention/model invariants (hypothesis + targeted)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_smoke_config
from repro.models import transformer as model
from repro.models.attention import sdpa
from repro.models.rope import mrope_angles

SETTINGS = dict(max_examples=20, deadline=None)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2 ** 16), kh=st.sampled_from([1, 2, 4]))
def test_sdpa_rows_are_convex_combinations(seed, kh):
    """Attention outputs lie in the convex hull of V rows: per-coordinate
    min(V) <= out <= max(V)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 8, 4, 16))
    k = jax.random.normal(ks[1], (1, 8, kh, 16))
    v = jax.random.normal(ks[2], (1, 8, kh, 16))
    out = np.asarray(sdpa(q, k, v, None))
    vmin, vmax = np.asarray(v).min(), np.asarray(v).max()
    assert (out >= vmin - 1e-5).all() and (out <= vmax + 1e-5).all()


def test_causality_no_future_leak():
    """Perturbing token t must not change logits at positions < t, for a
    causal decoder of every block family.

    MoE archs need ample router capacity here: with a tight capacity
    factor, a future token can displace an earlier one from an expert's
    buffer (GShard capacity contention is global over the sequence) — an
    expected MoE property, not an attention-causality bug (verified: leak
    vanishes at capacity_factor=8)."""
    for arch in ("qwen2.5-14b", "rwkv6-7b", "zamba2-2.7b",
                 "deepseek-v3-671b"):
        cfg = get_smoke_config(arch).replace(dtype="float32", mtp_depth=0)
        if cfg.moe:
            cfg = cfg.replace(moe=dataclasses.replace(
                cfg.moe, capacity_factor=8.0))
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0,
                                  cfg.vocab_size)
        l1, _, _ = model.forward(cfg, params, {"tokens": toks}, mode="train")
        toks2 = toks.at[0, 16].set((toks[0, 16] + 7) % cfg.vocab_size)
        l2, _, _ = model.forward(cfg, params, {"tokens": toks2},
                                 mode="train")
        diff = np.abs(np.asarray(l1 - l2))[0]
        assert diff[:16].max() < 1e-5, arch    # past unchanged
        assert diff[16:].max() > 1e-6, arch    # future did change


def test_encoder_is_bidirectional():
    cfg = get_smoke_config("hubert-xlarge").replace(dtype="float32")
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    from repro.launch.specs import concrete_batch
    batch = concrete_batch(cfg, 1, 24, seed=0)
    # no masking: a masked position would hide the feature perturbation
    batch["mask"] = jnp.zeros_like(batch["mask"])
    l1, _, _ = model.forward(cfg, params, batch, mode="train")
    b2 = dict(batch)
    b2["features"] = batch["features"].at[0, 20].add(1.0)
    l2, _, _ = model.forward(cfg, params, b2, mode="train")
    diff = np.abs(np.asarray(l1 - l2))[0]
    assert diff[:20].max() > 1e-6     # earlier positions see the change


def test_mrope_sections_independent():
    """M-RoPE: a section's angle depends only on its own position stream."""
    pos = jnp.zeros((1, 4, 3), jnp.int32)
    a0 = mrope_angles(pos, 32, 10000.0, (6, 5, 5))
    pos_t = pos.at[..., 0].set(7)      # change temporal only
    a1 = mrope_angles(pos_t, 32, 10000.0, (6, 5, 5))
    d = np.abs(np.asarray(a1 - a0))[0, 0]
    assert (d[:6] > 0).all()           # temporal section moved
    np.testing.assert_allclose(d[6:], 0.0)   # h/w sections untouched


def test_mla_absorbed_equals_materialized():
    """MLA decode (absorbed, latent-space) == train-mode attention math."""
    cfg = get_smoke_config("deepseek-v3-671b").replace(
        dtype="float32", mtp_depth=0)
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    S = 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S + 1), 0,
                              cfg.vocab_size)
    ref, _, _ = model.forward(cfg, params, {"tokens": toks}, mode="train")
    cache = model.init_cache(cfg, 2, S + 1, dtype=jnp.float32)
    _, cache, _ = model.forward(cfg, params, {"tokens": toks[:, :S]},
                                mode="prefill", cache=cache)
    dl, _, _ = model.forward(cfg, params,
                             {"token": toks[:, S:S + 1],
                              "index": jnp.int32(S)},
                             mode="decode", cache=cache)
    np.testing.assert_allclose(np.asarray(dl[:, 0]), np.asarray(ref[:, S]),
                               atol=5e-4)


@pytest.mark.parametrize("arch", ["gemma3-12b"])
def test_softcap_path(arch):
    """Logit softcapping changes outputs and keeps them bounded-ish."""
    cfg = get_smoke_config(arch).replace(dtype="float32")
    cfg2 = cfg.replace(attn_logit_softcap=5.0)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                              cfg.vocab_size)
    l1, _, _ = model.forward(cfg, params, {"tokens": toks}, mode="train")
    l2, _, _ = model.forward(cfg2, params, {"tokens": toks}, mode="train")
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-5
    assert np.isfinite(np.asarray(l2)).all()
