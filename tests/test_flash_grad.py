"""Gradient parity for the differentiable flash kernel: jax.grad through
``flash_mha``/``flash_attention`` must match grads through the pure-jnp
``ref_attention``/``sdpa`` oracles (interpret=True executes the Pallas
dq and dk/dv backward kernels on CPU)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention as fa
from repro.kernels.flash_attention import flash_attention, flash_attention_fwd
from repro.kernels.ops import flash_mha
from repro.kernels.ref import ref_attention
from repro.models.attention import _mask, sdpa

KEY = jax.random.PRNGKey(7)


def _qkvw(b, h, kh, s, d, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, kh, s, d), dtype)
    v = jax.random.normal(ks[2], (b, kh, s, d), dtype)
    w = jax.random.normal(ks[3], (b, h, s, d))      # fixed cotangent weights
    return q, k, v, w


def _grads(attn_fn, q, k, v, w):
    def loss(q, k, v):
        return jnp.sum(attn_fn(q, k, v).astype(jnp.float32) * w)
    return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)


def _assert_close(got, want, *, rtol, atol):
    for g, r in zip(got, want):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(r, np.float32),
                                   rtol=rtol, atol=atol)


CASES = [
    # b, h, kh, s, d, causal, window
    (1, 4, 4, 128, 32, True, 0),      # causal MHA
    (1, 4, 4, 128, 32, False, 0),     # non-causal (ViT encoder)
    (2, 8, 2, 128, 32, True, 0),      # GQA: dk/dv accumulate over the group
    (1, 4, 2, 128, 32, True, 48),     # sliding window + GQA
    (1, 2, 2, 100, 32, True, 0),      # ragged tail: s % block != 0
    (1, 2, 1, 100, 32, False, 24),    # ragged + bidirectional window + MQA
]


@pytest.mark.parametrize("b,h,kh,s,d,causal,window", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_grad_matches_ref(b, h, kh, s, d, causal, window, dtype):
    q, k, v, w = _qkvw(b, h, kh, s, d, dtype)
    flash = functools.partial(flash_attention, causal=causal, window=window,
                              block_q=32, block_k=32, interpret=True)
    ref = functools.partial(ref_attention, causal=causal, window=window)
    got = _grads(flash, q, k, v, w)
    for g, x in zip(got, (q, k, v)):
        assert g.dtype == x.dtype and g.shape == x.shape
    if dtype == jnp.float32:
        want = _grads(ref, q, k, v, w)
        _assert_close(got, want, rtol=1e-4, atol=1e-4)
    else:
        # bf16: compare against the fp32 oracle; 2e-2 is sub-ulp at the
        # observed grad magnitudes (fp32 accumulation inside the kernel)
        want = _grads(ref, *(x.astype(jnp.float32) for x in (q, k, v)), w)
        _assert_close(got, want, rtol=2e-2, atol=2e-2)


def test_flash_mha_grad_matches_sdpa():
    """Model layout end-to-end: grads through the ops.flash_mha wrapper
    (the path attention_block takes) vs grads through sdpa."""
    b, s, h, kh, d = 2, 96, 4, 2, 32
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kh, d))
    v = jax.random.normal(ks[2], (b, s, kh, d))
    w = jax.random.normal(ks[3], (b, s, h, d))
    mask = _mask(jnp.arange(s)[None], jnp.arange(s)[None], causal=True,
                 window=0)[:, None, None]
    flash = functools.partial(flash_mha, causal=True, window=0, block_q=32,
                              block_k=32, interpret=True)
    got = _grads(flash, q, k, v, w)
    want = _grads(lambda q, k, v: sdpa(q, k, v, mask), q, k, v, w)
    _assert_close(got, want, rtol=1e-4, atol=1e-4)


def test_lse_residual_is_fp32():
    """The saved logsumexp residual: fp32, (B,H,S), matches the oracle."""
    b, h, s, d = 1, 2, 96, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, h, s, d), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, h, s, d), jnp.bfloat16)
    out, lse = flash_attention_fwd(q, k, v, causal=True, block_q=32,
                                   block_k=32, interpret=True)
    assert lse.dtype == jnp.float32
    assert lse.shape == (b, h, s)
    assert out.dtype == q.dtype
    scores = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * d ** -0.5
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    scores = jnp.where(kp <= qp, scores, -jnp.inf)
    want = jax.scipy.special.logsumexp(scores, axis=-1)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(want),
                               rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 40),
                                           (False, 56)])
def test_block_skip_parity(causal, window):
    """Pruned and unpruned kernels agree on outputs AND gradients — skipped
    blocks contribute exactly zero in the unpruned path too."""
    b, h, kh, s, d = 1, 4, 2, 128, 32
    q, k, v, w = _qkvw(b, h, kh, s, d, jnp.float32)
    mk = lambda skip: functools.partial(
        flash_attention, causal=causal, window=window, block_q=32,
        block_k=32, interpret=True, block_skip=skip)
    np.testing.assert_allclose(np.asarray(mk(True)(q, k, v)),
                               np.asarray(mk(False)(q, k, v)), atol=1e-6)
    _assert_close(_grads(mk(True), q, k, v, w),
                  _grads(mk(False), q, k, v, w), rtol=1e-5, atol=1e-5)


def test_no_interpreter_differentiation():
    """Structural: flash_attention is backed by a custom VJP, so jax.grad
    can never fall back to differentiating the forward interpreter."""
    assert isinstance(fa._flash, jax.custom_vjp)
    # and the VJP engages under jit+grad with a traced window scalar
    b, h, s, d = 1, 2, 64, 16
    q, k, v, w = _qkvw(b, h, h, s, d, jnp.float32)

    @jax.jit
    def loss(q, k, v, window):
        out = flash_attention(q, k, v, causal=True, window=window,
                              block_q=32, block_k=32, interpret=True)
        return jnp.sum(out.astype(jnp.float32) * w)

    g = jax.grad(loss)(q, k, v, jnp.int32(24))
    assert np.isfinite(np.asarray(g)).all()


def test_vit_train_step_use_pallas_grads_match_naive():
    """End-to-end wiring: the ViT (non-causal encoder, the paper's workload)
    trains through the flash VJP when use_pallas=True, and its parameter
    gradients match the naive sdpa path."""
    from repro.configs import get_smoke_config
    from repro.models import transformer as model

    cfg0 = get_smoke_config("vit-b16").replace(dtype="float32")
    cfg1 = cfg0.replace(use_pallas=True)
    params = model.init_params(cfg0, KEY)
    ks = jax.random.split(KEY, 2)
    batch = {
        "images": jax.random.normal(ks[0], (2, cfg0.image_size,
                                            cfg0.image_size, 3)),
        "labels": jax.random.randint(ks[1], (2,), 0, cfg0.num_classes),
    }
    l0 = model.loss_fn(cfg0, params, batch)[0]
    l1 = model.loss_fn(cfg1, params, batch)[0]
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
    g0 = jax.grad(lambda p: model.loss_fn(cfg0, p, batch)[0])(params)
    g1 = jax.grad(lambda p: model.loss_fn(cfg1, p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_gqa_train_use_pallas_grads_match_naive():
    """GQA decoder train path (causal + per-layer sliding windows) through
    the flash VJP vs the naive masked-sdpa path."""
    from repro.configs import get_smoke_config
    from repro.models import transformer as model

    cfg0 = get_smoke_config("gemma3-12b").replace(dtype="float32",
                                                  mtp_depth=0)
    cfg1 = cfg0.replace(use_pallas=True)
    params = model.init_params(cfg0, KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg0.vocab_size)}
    l0 = model.loss_fn(cfg0, params, batch)[0]
    l1 = model.loss_fn(cfg1, params, batch)[0]
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
    g0 = jax.grad(lambda p: model.loss_fn(cfg0, p, batch)[0])(params)
    g1 = jax.grad(lambda p: model.loss_fn(cfg1, p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
