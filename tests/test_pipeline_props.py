"""Property tests for the 1F1B schedule simulator (core/pipeline.py) —
flat and Megatron-interleaved: in-flight residual bounds (the memory
invariant the staged executor's residual store relies on), makespan
monotonicity in the interleave factor, and flat-schedule recovery at v=1.
"""
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import pipeline

SETTINGS = dict(max_examples=30, deadline=None)

# (S, M multiple of S) grids small enough to simulate fast
stages_st = st.sampled_from([2, 3, 4])
mult_st = st.integers(1, 4)
v_st = st.sampled_from([1, 2, 3, 4])


def in_flight_trace(sched, dev):
    """Per-tick count of live residual sets on ``dev`` (F acquires one
    microbatch's chunk-input residual, B releases it)."""
    live, trace = 0, []
    for task in sched[dev]:
        if task is not None:
            live += 1 if task.kind == "F" else -1
        trace.append(live)
    return trace


# ---------------------------------------------------------------------------
# in-flight residual bounds
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(stages=stages_st, mult=st.integers(1, 6))
def test_flat_in_flight_bounded_by_stages(stages, mult):
    """v=1 keeps the strict 1F1B cap: device d never holds more than
    S - d in-flight residual sets, independent of M."""
    micro = stages * mult
    sched = pipeline.one_f_one_b(micro, stages, interleave=1)
    for d in range(stages):
        assert max(in_flight_trace(sched, d)) <= stages - d, (d, micro)


@settings(**SETTINGS)
@given(stages=stages_st, mult=mult_st, v=st.sampled_from([2, 3, 4]))
def test_interleaved_in_flight_bounded_by_warmup(stages, mult, v):
    """Interleaved: per-device in-flight residuals never exceed the
    warmup depth + 1 = min(2*(S-d-1) + (v-1)*S, v*M) + 1 — flat in M,
    which is what makes the staged executor memory-bounded."""
    micro = stages * mult
    sched = pipeline.one_f_one_b(micro, stages, interleave=v)
    for d in range(stages):
        cap = min(2 * (stages - d - 1) + (v - 1) * stages,
                  v * micro) + 1
        assert max(in_flight_trace(sched, d)) <= cap, (d, micro, v)


@settings(**SETTINGS)
@given(stages=stages_st, mult=mult_st, v=v_st)
def test_in_flight_never_negative_and_drains(stages, mult, v):
    """No backward fires before its forward, and every residual is
    released by the end of the schedule."""
    micro = stages * mult
    sched = pipeline.one_f_one_b(micro, stages, interleave=v)
    for d in range(stages):
        trace = in_flight_trace(sched, d)
        assert min(trace) >= 0, (d, micro, v)
        assert trace[-1] == 0, (d, micro, v)


# ---------------------------------------------------------------------------
# makespan / bubble monotonicity
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(stages=stages_st, mult=mult_st)
def test_normalized_makespan_monotone_in_v(stages, mult):
    """One interleaved slot is 1/v of a flat slot, so makespan/v is the
    comparable wall-clock: it must be non-increasing in v (more virtual
    chunks never lengthen the pipeline)."""
    micro = stages * mult
    norms = [pipeline.makespan(pipeline.one_f_one_b(
        micro, stages, interleave=v)) / v for v in (1, 2, 3, 4)]
    for a, b in zip(norms, norms[1:]):
        assert b <= a + 1e-9, norms


@settings(**SETTINGS)
@given(stages=stages_st, mult=mult_st, v=v_st)
def test_bubble_fraction_shrinks_toward_interleaved_ideal(stages, mult, v):
    micro = stages * mult
    frac = pipeline.simulated_bubble_fraction(micro, stages, v)
    assert frac == pytest.approx(
        (stages - 1) / (v * micro + stages - 1))


# ---------------------------------------------------------------------------
# v=1 equivalence
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(stages=stages_st, mult=st.integers(1, 6))
def test_flat_recovered_at_v1(stages, mult):
    micro = stages * mult
    assert pipeline.one_f_one_b(micro, stages, interleave=1) == \
        pipeline.one_f_one_b(micro, stages)


@settings(**SETTINGS)
@given(stages=stages_st, mult=mult_st, v=v_st)
def test_accounting_consistent(stages, mult, v):
    """F == B == v*M slots per device and F + B + idle == ticks."""
    micro = stages * mult
    acc = pipeline.schedule_accounting(micro, stages, v)
    for d in range(stages):
        assert acc["F"][d] == v * micro
        assert acc["B"][d] == v * micro
        assert acc["F"][d] + acc["B"][d] + acc["idle"][d] == acc["ticks"]
