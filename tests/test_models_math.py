"""Numerical equivalence tests for the model-math building blocks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import mamba2 as m2
from repro.models.blockwise import blockwise_attention
from repro.models.rwkv6 import wkv6_chunked
from repro.kernels.ref import ref_attention, ref_wkv6

KEY = jax.random.PRNGKey(7)


def _mamba_sequential_ref(xh, bmat, cmat, dt, a_log, h0):
    """Definitional per-step SSD recurrence."""
    f32 = jnp.float32
    xh, bmat, cmat, dt = (t.astype(f32) for t in (xh, bmat, cmat, dt))
    A = -jnp.exp(a_log.astype(f32))

    def step(h, inp):
        x_t, b_t, c_t, dt_t = inp
        a_t = jnp.exp(dt_t * A)                     # (B,H)
        h = a_t[..., None, None] * h + jnp.einsum(
            "bhp,bn->bhpn", x_t * dt_t[..., None], b_t)
        y = jnp.einsum("bhpn,bn->bhp", h, c_t)
        return h, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xh, bmat, cmat, dt))
    h_end, ys = jax.lax.scan(step, h0.astype(f32), xs)
    return jnp.moveaxis(ys, 0, 1), h_end


@pytest.mark.parametrize("s,chunk", [(64, 16), (100, 32), (32, 32)])
def test_mamba2_chunked_equals_sequential(s, chunk):
    b, h, p, n = 2, 3, 8, 4
    ks = jax.random.split(KEY, 5)
    xh = jax.random.normal(ks[0], (b, s, h, p))
    bmat = jax.random.normal(ks[1], (b, s, n))
    cmat = jax.random.normal(ks[2], (b, s, n))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h)))
    a_log = jax.random.normal(ks[4], (h,)) * 0.3
    h0 = jnp.zeros((b, h, p, n))
    y_c, h_c = m2._ssd_chunk_scan(xh, bmat, cmat, dt, a_log, chunk, h0)
    y_r, h_r = _mamba_sequential_ref(xh, bmat, cmat, dt, a_log, h0)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_r), atol=2e-4)


def test_mamba2_chunked_carries_state():
    """Splitting a sequence across two chunked calls == one call."""
    b, s, h, p, n = 1, 64, 2, 8, 4
    ks = jax.random.split(KEY, 5)
    xh = jax.random.normal(ks[0], (b, s, h, p))
    bmat = jax.random.normal(ks[1], (b, s, n))
    cmat = jax.random.normal(ks[2], (b, s, n))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h)))
    a_log = jax.random.normal(ks[4], (h,)) * 0.3
    h0 = jnp.zeros((b, h, p, n))
    y_full, h_full = m2._ssd_chunk_scan(xh, bmat, cmat, dt, a_log, 16, h0)
    y1, h_mid = m2._ssd_chunk_scan(xh[:, :32], bmat[:, :32], cmat[:, :32],
                                   dt[:, :32], a_log, 16, h0)
    y2, h_end = m2._ssd_chunk_scan(xh[:, 32:], bmat[:, 32:], cmat[:, 32:],
                                   dt[:, 32:], a_log, 16, h_mid)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_end), np.asarray(h_full),
                               atol=2e-4)


@pytest.mark.parametrize("s,chunk", [(64, 16), (100, 32)])
def test_wkv6_jnp_chunked_vs_sequential(s, chunk):
    b, h, p = 2, 2, 16
    ks = jax.random.split(KEY, 6)
    r, k, v = (jax.random.normal(ks[i], (b, s, h, p)) for i in range(3))
    wlog = -jnp.exp(jax.random.normal(ks[3], (b, s, h, p)) - 0.5)
    u = 0.3 * jax.random.normal(ks[4], (h, p))
    s0 = 0.1 * jax.random.normal(ks[5], (b, h, p, p))
    o_c, s_c = wkv6_chunked(r, k, v, wlog, u, chunk, s0)
    o_r, s_r = ref_wkv6(r, k, v, wlog, u, s0)
    np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_r), atol=5e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_r), atol=5e-4)


@pytest.mark.parametrize("causal,window,bk", [
    (True, 0, 64), (True, 32, 32), (False, 0, 128), (True, 0, 48),
])
def test_blockwise_attention_fwd_bwd(causal, window, bk):
    b, s, h, kh, d = 2, 128, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kh, d))
    v = jax.random.normal(ks[2], (b, s, kh, d))

    def f_block(q, k, v):
        return jnp.sum(jnp.sin(
            blockwise_attention(q, k, v, window, causal=causal, block_k=bk)))

    def f_ref(q, k, v):
        o = ref_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=causal,
                          window=window)
        return jnp.sum(jnp.sin(o.transpose(0, 2, 1, 3)))

    np.testing.assert_allclose(float(f_block(q, k, v)), float(f_ref(q, k, v)),
                               rtol=1e-5)
    g1 = jax.grad(f_block, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, (0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-4)


def test_gemma_window_pattern_affects_logits():
    """Sliding window must actually mask: full-window vs tiny-window logits
    differ for long-range tokens."""
    from repro.models import transformer as model
    cfg = get_smoke_config("gemma3-12b").replace(dtype="float32")
    params = model.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (1, 64), 0, cfg.vocab_size)
    l1, _, _ = model.forward(cfg, params, {"tokens": toks}, mode="train")
    cfg2 = cfg.replace(sliding_window=4)
    l2, _, _ = model.forward(cfg2, params, {"tokens": toks}, mode="train")
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-3


def test_mla_latent_cache_is_compressed():
    """The MLA cache must be (kv_lora + rope) wide, not H*(nope+v)."""
    from repro.models import transformer as model
    cfg = get_smoke_config("deepseek-v3-671b")
    cache = jax.eval_shape(
        lambda: model.init_cache(cfg, 2, 32, jnp.bfloat16))
    moe_c = cache["moe"]
    assert moe_c["c_kv"].shape[-1] == cfg.mla.kv_lora_rank
    assert moe_c["k_rope"].shape[-1] == cfg.mla.qk_rope_head_dim
    from repro.configs import get_config
    full_cfg = get_config("deepseek-v3-671b")
    full_kv_width = full_cfg.num_heads * (full_cfg.mla.qk_nope_head_dim
                                          + full_cfg.mla.v_head_dim)
    latent_width = full_cfg.mla.kv_lora_rank + full_cfg.mla.qk_rope_head_dim
    assert full_kv_width / latent_width > 50   # the ~57x saving
