import os
import sys

# tests see the SINGLE real device (the dry-run alone forces 512); multi-
# device integration tests spawn subprocesses with their own XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)


def run_subprocess(code: str, devices: int = 8, timeout: int = 600):
    """Run python code in a subprocess with N host platform devices."""
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout
