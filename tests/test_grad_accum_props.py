"""Property tests for core/grad_accum.py: split_microbatches round-trip,
accumulation linearity, non-divisible-batch behavior, and the narrowed
_constrain_tree no-mesh handling (ZeRO-2's reduce-scatter constraint must
never be silently dropped under a live mesh)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import grad_accum
from repro.core.grad_accum import accumulate_gradients, split_microbatches

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# split_microbatches
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(accum=st.sampled_from([1, 2, 4, 8]),
       per_mb=st.integers(1, 4),
       trailing=st.sampled_from([(), (3,), (2, 5)]),
       seed=st.integers(0, 2 ** 16))
def test_split_microbatches_round_trip(accum, per_mb, trailing, seed):
    """Reshape inverse: concatenating the microbatches restores the batch,
    leaf by leaf, in order."""
    b = accum * per_mb
    key = jax.random.PRNGKey(seed)
    batch = {"x": jax.random.normal(key, (b,) + trailing),
             "y": jnp.arange(b, dtype=jnp.int32)}
    mbs = jax.tree.map(np.asarray, split_microbatches(batch, accum))
    for k, leaf in batch.items():
        assert mbs[k].shape == (accum, per_mb) + leaf.shape[1:]
        np.testing.assert_array_equal(
            mbs[k].reshape(leaf.shape), np.asarray(leaf))


@settings(**SETTINGS)
@given(accum=st.sampled_from([1, 3, 5]), seed=st.integers(0, 2 ** 16))
def test_split_microbatches_scalar_leaf_broadcast(accum, seed):
    """Scalar leaves (step counters, shared flags) broadcast to (accum,), so
    every microbatch sees the same value."""
    val = jnp.float32(seed)
    mbs = split_microbatches({"x": jnp.zeros((accum, 2)), "s": val}, accum)
    assert mbs["s"].shape == (accum,)
    np.testing.assert_array_equal(np.asarray(mbs["s"]),
                                  np.full((accum,), float(seed), np.float32))


@pytest.mark.parametrize("batch,accum", [(6, 4), (3, 2), (8, 3)])
def test_split_microbatches_non_divisible_asserts(batch, accum):
    with pytest.raises(AssertionError):
        split_microbatches({"x": jnp.zeros((batch, 2))}, accum)


# ---------------------------------------------------------------------------
# accumulation linearity
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(accum=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2 ** 16))
def test_accum_linearity(accum, seed):
    """Mean-of-microbatch-grads == single-shot grads (fp32 tolerance) for a
    mean-reduced loss: DeepSpeed's accumulation contract is exact."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    w = jax.random.normal(ks[0], (8, 4))
    batch = {"x": jax.random.normal(ks[1], (16, 8)),
             "y": jax.random.normal(ks[2], (16, 4))}

    def loss_fn(params, b):
        pred = jnp.tanh(b["x"] @ params)
        loss = jnp.mean((pred - b["y"]) ** 2)
        return loss, {"loss": loss}

    g1, _ = accumulate_gradients(loss_fn, w, batch, 1)
    gk, _ = accumulate_gradients(loss_fn, w, batch, accum)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(g1),
                               atol=1e-6, rtol=1e-5)


# ---------------------------------------------------------------------------
# _constrain_tree error narrowing
# ---------------------------------------------------------------------------

def test_constrain_tree_no_mesh_warns_once_and_passes_through(monkeypatch):
    from jax.sharding import PartitionSpec as P

    monkeypatch.setattr(grad_accum, "_warned_no_mesh", False)
    x = {"w": jnp.ones((4, 2))}
    specs = {"w": P("data")}

    @jax.jit
    def f(x):
        return grad_accum._constrain_tree(x, specs)

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        out = f(x)                     # no mesh installed -> warn, not raise
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(x["w"]))
    msgs = [w for w in caught if issubclass(w.category, RuntimeWarning)
            and "no mesh installed" in str(w.message)]
    assert len(msgs) == 1, [str(w.message) for w in caught]


def test_constrain_tree_reraises_non_mesh_errors():
    """A genuinely bad spec (not the no-mesh case) must surface, not be
    swallowed — that is how ZeRO-2's reduce-scatter was silently lost."""
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import PartitionSpec as P

    x = {"w": jnp.ones((4, 2))}
    specs = {"w": P("nonexistent_axis")}
    with mesh:
        with pytest.raises((ValueError, KeyError)):
            jax.jit(lambda x: grad_accum._constrain_tree(x, specs))(x)


def test_constrain_tree_applies_under_mesh():
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    x = {"w": jnp.ones((4, 2))}
    with mesh:
        out = jax.jit(
            lambda x: grad_accum._constrain_tree(x, {"w": P("data")}))(x)
    assert out["w"].sharding == NamedSharding(mesh, P("data"))


# ---------------------------------------------------------------------------
# fp32 accumulation under bf16 compute (the cast_params_bf16 contract)
# ---------------------------------------------------------------------------

def test_accumulation_stays_fp32_under_bf16_params():
    """accumulate_gradients must return fp32 accumulators even when the
    compute params (and hence per-microbatch grads) are bf16."""
    params = {"w": jnp.ones((8, 4), jnp.bfloat16)}
    batch = {"x": jnp.ones((8, 8), jnp.bfloat16)}

    def loss_fn(p, b):
        loss = jnp.mean((b["x"] @ p["w"]) ** 2)
        return loss.astype(jnp.bfloat16), {}

    g, _ = accumulate_gradients(loss_fn, params, batch, 4)
    assert g["w"].dtype == jnp.float32


def test_pipeline_grads_stay_fp32_under_bf16_params():
    """The staged 1F1B path accumulates per-chunk VJP cotangents in fp32
    regardless of compute dtype — what makes cast_params_bf16 legal under
    pipeline parallelism (fp32 master grads from bf16 stage compute)."""
    from repro.configs import get_smoke_config
    from repro.core import pipeline
    from repro.launch.specs import concrete_batch
    from repro.models import transformer as model

    cfg = get_smoke_config("vit-b16").replace(dtype="float32", num_layers=2)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    bf16 = jax.tree.map(
        lambda p: p.astype(jnp.bfloat16)
        if p.dtype == jnp.float32 and p.ndim >= 2 else p, params)
    batch = concrete_batch(cfg, 4, 32, seed=0)
    (_, _), grads = pipeline.pipelined_value_and_grad(
        cfg, bf16, batch, stages=2, num_micro=2, pipe_axis=None)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert g.dtype == jnp.float32, jax.tree_util.keystr(path)


# ---------------------------------------------------------------------------
# per-microbatch rng threading (the TrainState rng plumbing)
# ---------------------------------------------------------------------------

def test_rngs_are_inert_for_deterministic_losses():
    """Passing rngs to a loss that ignores them must not change gradients
    (the engine always threads them; deterministic archs DCE the stream)."""
    params = {"w": jnp.arange(4.0)}
    batch = {"x": jnp.arange(8.0).reshape(8, 1)}

    def loss_no_rng(p, mb):
        return jnp.mean(mb["x"] * p["w"]), {}

    def loss_rng(p, mb, rng):
        del rng
        return loss_no_rng(p, mb)

    g0, _ = accumulate_gradients(loss_no_rng, params, batch, 4)
    rngs = jax.random.split(jax.random.PRNGKey(0), 4)
    g1, _ = accumulate_gradients(loss_rng, params, batch, 4, rngs=rngs)
    np.testing.assert_array_equal(np.asarray(g0["w"]), np.asarray(g1["w"]))


@pytest.mark.parametrize("accum", [1, 4])
def test_rngs_deliver_per_microbatch_keys(accum):
    """Each microbatch must see ITS key: a loss whose gradient is the
    rng draw itself reconstructs exactly the mean over the key stack."""
    params = {"w": jnp.zeros(())}
    batch = {"x": jnp.zeros((accum,))}
    rngs = jax.random.split(jax.random.PRNGKey(7), accum)

    def loss(p, mb, rng):
        return p["w"] * jax.random.uniform(rng, ()), {}

    g, _ = accumulate_gradients(loss, params, batch, accum, rngs=rngs)
    want = np.mean([float(jax.random.uniform(r, ())) for r in rngs])
    np.testing.assert_allclose(float(g["w"]), want, rtol=1e-6)
