"""Hypothesis property-based tests on system invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.grad_accum import accumulate_gradients, split_microbatches
from repro.core.comm_model import (
    StepModel,
    allreduce_time,
    strong_scaling_times,
    weak_scaling_times,
)
from repro.models.moe import top_k_routing
from repro.models.norms import rmsnorm
from repro.models.rope import apply_rope
from repro.optim import make_optimizer

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# DeepSpeed batch semantics: accumulation is exact averaging
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(accum=st.sampled_from([1, 2, 4, 8]), seed=st.integers(0, 2 ** 16))
def test_grad_accum_equals_full_batch(accum, seed):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (8, 4))
    batch = {"x": jax.random.normal(key, (16, 8)),
             "y": jax.random.normal(key, (16, 4))}

    def loss_fn(params, b):
        pred = b["x"] @ params
        loss = jnp.mean((pred - b["y"]) ** 2)
        return loss, {"loss": loss}

    g1, _ = accumulate_gradients(loss_fn, w, batch, 1)
    gk, _ = accumulate_gradients(loss_fn, w, batch, accum)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(gk),
                               atol=1e-5)


@settings(**SETTINGS)
@given(b=st.sampled_from([4, 8, 24]), accum=st.sampled_from([1, 2, 4]))
def test_split_microbatches_partition(b, accum):
    if b % accum:
        return
    x = jnp.arange(b * 3).reshape(b, 3)
    mbs = split_microbatches({"x": x}, accum)
    assert mbs["x"].shape == (accum, b // accum, 3)
    np.testing.assert_array_equal(
        np.asarray(mbs["x"].reshape(b, 3)), np.asarray(x))


# ---------------------------------------------------------------------------
# RMSNorm invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(seed=st.integers(0, 2 ** 16),
       scale_pow=st.floats(-2.0, 2.0))
def test_rmsnorm_scale_invariance(seed, scale_pow):
    """rmsnorm(c*x) == rmsnorm(x) for c > 0 (up to eps)."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (4, 64)) + 0.1
    c = 10.0 ** scale_pow
    sc = jnp.ones((64,))
    a = rmsnorm(x, sc, 1e-8)
    b = rmsnorm(c * x, sc, 1e-8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2 ** 16))
def test_rmsnorm_unit_rms(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (8, 128))
    out = rmsnorm(x, jnp.ones((128,)), 1e-8)
    rms = np.sqrt(np.mean(np.asarray(out) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


# ---------------------------------------------------------------------------
# RoPE: norm preservation + relative-position property
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(seed=st.integers(0, 2 ** 16), style=st.sampled_from(["full", "half"]))
def test_rope_preserves_norm(seed, style):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (1, 16, 2, 32))
    pos = jnp.broadcast_to(jnp.arange(16)[None], (1, 16))
    q2, _ = apply_rope(q, q, pos, style=style, theta=10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(q), axis=-1),
        np.linalg.norm(np.asarray(q2), axis=-1), rtol=1e-5)


@settings(**SETTINGS)
@given(shift=st.integers(0, 32))
def test_rope_relative_property(shift):
    """<rope(q,i), rope(k,j)> depends only on i-j: shifting both positions
    by the same offset leaves q·k unchanged."""
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 8, 1, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 1, 64))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (1, 8))
    q1, k1 = apply_rope(q, k, pos, style="full", theta=10000.0)
    q2, k2 = apply_rope(q, k, pos + shift, style="full", theta=10000.0)
    dots1 = np.einsum("bshd,bthd->bst", np.asarray(q1), np.asarray(k1))
    dots2 = np.einsum("bshd,bthd->bst", np.asarray(q2), np.asarray(k2))
    np.testing.assert_allclose(dots1, dots2, atol=1e-3)


# ---------------------------------------------------------------------------
# MoE routing invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(seed=st.integers(0, 2 ** 16), e=st.sampled_from([4, 8]),
       k=st.sampled_from([1, 2]))
def test_router_dispatch_invariants(seed, e, k):
    b, s = 2, 16
    logits = jax.random.normal(jax.random.PRNGKey(seed), (b, s, e))
    capacity = s  # ample
    dispatch, combine, aux = top_k_routing(logits, k, capacity)
    d = np.asarray(dispatch)
    c = np.asarray(combine)
    # each token dispatched to exactly k (expert, slot) pairs
    np.testing.assert_allclose(d.sum((-1, -2)), k, atol=1e-6)
    # each capacity slot holds at most one token
    assert (d.sum(1) <= 1 + 1e-6).all()
    # combine weights live exactly where dispatch does, and sum to the
    # selected top-k softmax mass (<= 1)
    assert ((c > 0) <= (d > 0)).all()
    total = c.sum((-1, -2))
    assert (total <= 1 + 1e-5).all()
    assert float(aux) > 0


def test_router_aux_uniform_is_one():
    """Perfectly uniform router -> aux loss == 1 (switch normalization)."""
    b, s, e = 4, 64, 8
    logits = jnp.zeros((b, s, e))
    _, _, aux = top_k_routing(logits, 2, s)
    np.testing.assert_allclose(float(aux), 1.0, rtol=0.15)


# ---------------------------------------------------------------------------
# Optimizers: descent on a quadratic
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(name=st.sampled_from(["adamw", "sgd", "lamb"]))
def test_optimizer_descends(name):
    opt = make_optimizer(name, weight_decay=0.0, grad_clip=0.0)
    w = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = opt.init(w)

    def loss(w):
        return jnp.sum(w["w"] ** 2)

    l0 = float(loss(w))
    for _ in range(50):
        g = jax.grad(loss)(w)
        w, state, _ = opt.update(g, state, w, 0.05)
    assert float(loss(w)) < l0 * 0.5


# ---------------------------------------------------------------------------
# Comm model properties (the scaling simulator the figures rely on)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(nbytes=st.floats(1e3, 1e10), n=st.integers(2, 512))
def test_allreduce_monotone_in_bytes(nbytes, n):
    assert allreduce_time(nbytes, n, 5e10) <= allreduce_time(
        nbytes * 2, n, 5e10) + 1e-12


def test_strong_scaling_improves_then_saturates():
    t = strong_scaling_times(10.0, 400e6, [1, 2, 4, 8, 16, 32])
    assert t[1] < t[0] and t[2] < t[1]           # early speedup
    speedup = t[0] / np.array(t)
    assert speedup[-1] < 32                      # sub-ideal (comm overhead)


def test_weak_scaling_flat_homogeneous():
    t = weak_scaling_times(1.0, 400e6, [1, 2, 4, 8])
    assert max(t) / min(t) < 1.2                 # near-constant


def test_heterogeneous_cluster_straggles():
    """Paper §IV-B: adding slower GPUs (Tesla machines 0,3) can INCREASE
    strong-scaling step time."""
    hetero = [1.0, 1.0, 1.0, 0.3, 0.27]          # rtx3070s + gtx1070 + p4
    t = strong_scaling_times(10.0, 400e6, [3, 5], hetero=hetero)
    assert t[1] > t[0] * 0.7                     # barely helps / hurts
    t_homo = strong_scaling_times(10.0, 400e6, [5])
    assert t[1] > t_homo[0]


def test_sync_fraction_drops_with_batch():
    """Paper Fig. 6: larger batch -> lower sync share of the step."""
    fracs = []
    for mb_scale in (1, 4, 16):
        m = StepModel(grad_bytes=400e6,
                      compute_times=[0.05 * mb_scale] * 4)
        fracs.append(m.sync_fraction())
    assert fracs[0] > fracs[1] > fracs[2]
