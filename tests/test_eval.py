"""Unit tests for the evaluation metric math (models/transformer.py):
classification_counts vs a numpy oracle (mask-aware integer counts +
NLL sum), the soft-label / label-smoothing cross-entropy, and the
engine's single-device eval loop plumbing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import EngineConfig, get_smoke_config
from repro.core.engine import DistributedEngine
from repro.data import CIFARSource
from repro.launch.mesh import make_local_mesh
from repro.models.transformer import _soft_xent, _xent, \
    classification_counts, loss_from_logits


def _np_counts(logits, labels, mask, topk=5):
    order = np.argsort(-logits, axis=-1)
    top1 = sum(int(m) for o, l, m in zip(order[:, 0], labels, mask)
               if o == l)
    top5 = sum(int(m) for o, l, m in zip(order[:, :topk], labels, mask)
               if l in o)
    p = logits - logits.max(-1, keepdims=True)
    logp = p - np.log(np.exp(p).sum(-1, keepdims=True))
    nll = -logp[np.arange(len(labels)), labels]
    return top1, top5, float((nll * mask).sum()), int(mask.sum())


def test_classification_counts_match_numpy_oracle():
    rng = np.random.default_rng(0)
    logits = rng.normal(0, 2, (17, 10)).astype(np.float32)
    labels = rng.integers(0, 10, (17,)).astype(np.int32)
    mask = (rng.random(17) > 0.3).astype(np.float32)
    got = classification_counts(jnp.asarray(logits), jnp.asarray(labels),
                                jnp.asarray(mask))
    t1, t5, ls, n = _np_counts(logits, labels, mask)
    assert int(got["top1"]) == t1
    assert int(got["top5"]) == t5
    assert int(got["count"]) == n
    np.testing.assert_allclose(float(got["loss_sum"]), ls, rtol=1e-5)
    assert got["top1"].dtype == jnp.int32
    assert got["top5"].dtype == jnp.int32


def test_classification_counts_default_mask_and_small_class_count():
    """No mask -> every example counts; top-5 clamps to the class count
    (top-k over 3 classes is always a hit)."""
    logits = jnp.asarray(np.random.default_rng(1).normal(size=(6, 3)),
                         jnp.float32)
    labels = jnp.asarray([0, 1, 2, 0, 1, 2], jnp.int32)
    got = classification_counts(logits, labels)
    assert int(got["count"]) == 6
    assert int(got["top5"]) == 6


def test_padded_examples_are_metric_invisible():
    """A zero-padded tail under a zero mask contributes nothing — the
    non-divisible final eval batch contract."""
    rng = np.random.default_rng(2)
    logits = rng.normal(0, 1, (8, 10)).astype(np.float32)
    labels = rng.integers(0, 10, (8,)).astype(np.int32)
    mask = np.asarray([1, 1, 1, 1, 1, 0, 0, 0], np.float32)
    a = classification_counts(jnp.asarray(logits), jnp.asarray(labels),
                              jnp.asarray(mask))
    # mutate the padded tail wildly: nothing may change
    logits[5:] = 1e6
    labels[5:] = 0
    b = classification_counts(jnp.asarray(logits), jnp.asarray(labels),
                              jnp.asarray(mask))
    for k in ("top1", "top5", "count"):
        assert int(a[k]) == int(b[k])
    np.testing.assert_allclose(float(a["loss_sum"]), float(b["loss_sum"]))


def test_soft_xent_reduces_to_hard_xent():
    """One-hot soft labels with no smoothing reproduce the hard-label CE
    (the soft path is a strict generalization)."""
    rng = np.random.default_rng(3)
    logits = jnp.asarray(rng.normal(0, 2, (9, 7)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 7, (9,)), jnp.int32)
    hard = _xent(logits, labels)
    soft = _soft_xent(logits, jax.nn.one_hot(labels, 7))
    np.testing.assert_allclose(float(hard), float(soft), rtol=1e-6)
    # hard ints through the soft path too (the smoothing-only case)
    np.testing.assert_allclose(float(_soft_xent(logits, labels)),
                               float(hard), rtol=1e-6)


def test_label_smoothing_formula():
    """smoothing eps mixes eps/C uniform mass into the target."""
    rng = np.random.default_rng(4)
    logits = jnp.asarray(rng.normal(0, 1, (5, 4)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 4, (5,)), jnp.int32)
    eps = 0.1
    got = float(_soft_xent(logits, labels, smoothing=eps))
    lp = np.asarray(logits, np.float64)
    lp = lp - np.log(np.exp(lp - lp.max(-1, keepdims=True)).sum(
        -1, keepdims=True)) - lp.max(-1, keepdims=True)
    y = np.eye(4)[np.asarray(labels)] * (1 - eps) + eps / 4
    np.testing.assert_allclose(got, float(np.mean(-(y * lp).sum(-1))),
                               rtol=1e-5)


def test_loss_from_logits_soft_label_path():
    """The vit loss accepts Mixup soft labels: accuracy is computed
    against the dominant class, and the smoothing knob engages."""
    cfg = get_smoke_config("vit-b16")
    rng = np.random.default_rng(5)
    logits = jnp.asarray(rng.normal(0, 1, (6, 10)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 10, (6,)), jnp.int32)
    lam = 0.7
    soft = lam * jax.nn.one_hot(labels, 10) + \
        (1 - lam) * jax.nn.one_hot(jnp.roll(labels, 1), 10)
    loss_s, m_s = loss_from_logits(cfg, logits, {"labels": soft})
    loss_h, m_h = loss_from_logits(cfg, logits, {"labels": labels})
    assert np.isfinite(float(loss_s))
    # dominant class of the soft target == the hard label (lam > 0.5)
    np.testing.assert_allclose(float(m_s["acc"]), float(m_h["acc"]))
    sm = cfg.replace(label_smoothing=0.1)
    loss_sm, _ = loss_from_logits(sm, logits, {"labels": labels})
    assert abs(float(loss_sm) - float(loss_h)) > 1e-6


def test_device_normalize_matches_host_reference():
    """The jitted fused cast-and-normalize (augment.normalize) must equal
    the host reference normalize_images to fp32 tolerance — the uint8-path
    parity pin."""
    from repro.data.augment import normalize, upsample
    from repro.data.datasets import _upsample, normalize_images
    src = CIFARSource("cifar10", seed=2, eval_size=16)
    u8 = next(src.eval_batches(16))["images"]
    ref = normalize_images(u8, src.mean, src.std)
    got = np.asarray(jax.jit(normalize, static_argnums=1)(
        jnp.asarray(u8), src.preproc))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
    # upsample parity too: device nearest-neighbor == host oracle
    np.testing.assert_array_equal(
        np.asarray(upsample(jnp.asarray(u8), 64)), _upsample(u8, 64))


def test_device_preprocess_requires_stats_for_uint8():
    from repro.data.augment import device_preprocess
    u8 = {"images": jnp.zeros((2, 32, 32, 3), jnp.uint8)}
    with pytest.raises(ValueError, match="no normalization statistics"):
        device_preprocess(u8, None, 32)
    f32 = {"images": jnp.zeros((2, 32, 32, 3), jnp.float32)}
    # float batches (legacy synthetic stream) pass through untouched
    np.testing.assert_array_equal(
        np.asarray(device_preprocess(f32, None, 32)["images"]),
        np.asarray(f32["images"]))


def test_engine_evaluate_single_device():
    """End-to-end eval loop on one device: counts accumulate across the
    padded UINT8 batch stream (preprocessed inside the jitted eval step)
    and rates derive from the exact split size."""
    cfg = get_smoke_config("vit-b16").replace(dtype="float32")
    src = CIFARSource("cifar10", seed=0, eval_size=21)
    eng = DistributedEngine(cfg, EngineConfig(train_batch_size=8,
                                              total_steps=10,
                                              warmup_steps=1),
                            make_local_mesh(), preproc=src.preproc)
    res = eng.evaluate(eng.init_state(seed=0), src.eval_batches(8))
    assert res["eval_count"] == 21
    assert 0 <= res["eval_top1_count"] <= res["eval_top5_count"] <= 21
    assert res["eval_acc"] == res["eval_top1_count"] / 21
    assert np.isfinite(res["eval_loss"])
    # deterministic: same state + split -> identical metrics
    res2 = eng.evaluate(eng.init_state(seed=0), src.eval_batches(8))
    assert res == res2


def test_engine_rejects_augment_with_pipeline_or_non_vit():
    from repro.data import AugmentConfig
    mesh = make_local_mesh()
    aug = AugmentConfig(num_classes=10)
    lm = get_smoke_config("qwen2.5-14b")
    with pytest.raises(ValueError, match="vit"):
        DistributedEngine(lm, EngineConfig(train_batch_size=8,
                                           total_steps=10), mesh, aug=aug)
    with pytest.raises(ValueError, match="num_classes"):
        AugmentConfig(num_classes=0).validate()


def test_engine_rejects_bad_preproc_wiring():
    from repro.data import Preproc
    mesh = make_local_mesh()
    pre = Preproc(mean=(0, 0, 0), std=(1, 1, 1), native_resolution=32)
    lm = get_smoke_config("qwen2.5-14b")
    with pytest.raises(ValueError, match="vit"):
        DistributedEngine(lm, EngineConfig(train_batch_size=8,
                                           total_steps=10), mesh,
                          preproc=pre)
    vit = get_smoke_config("vit-b16")       # image_size 32
    bad = Preproc(mean=(0, 0, 0), std=(1, 1, 1), native_resolution=28)
    with pytest.raises(ValueError, match="integer"):
        DistributedEngine(vit, EngineConfig(train_batch_size=8,
                                            total_steps=10), mesh,
                          preproc=bad)
