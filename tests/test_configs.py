"""Config registry + parameter-count sanity vs the public model cards."""
import pytest

from repro.configs import (
    ALL_ARCHS,
    ASSIGNED_ARCHS,
    get_config,
    get_shape,
    get_smoke_config,
)
from repro.configs.base import EngineConfig
from repro.configs.shapes import SHAPES, applicable

# (arch, expected params, rtol) — expected from the papers / model cards
EXPECTED_PARAMS = {
    "deepseek-v3-671b": (671e9, 0.10),
    "qwen2.5-14b": (14.8e9, 0.10),
    "qwen2-vl-72b": (72e9, 0.12),
    "hubert-xlarge": (1.0e9, 0.25),
    "glm4-9b": (9.4e9, 0.15),
    "zamba2-2.7b": (2.7e9, 0.30),
    "chatglm3-6b": (6.2e9, 0.15),
    "gemma3-12b": (12e9, 0.15),
    "rwkv6-7b": (7.6e9, 0.15),
    "granite-moe-3b-a800m": (3.3e9, 0.30),
    "vit-b16": (86e6, 0.15),
}


def test_registry_complete():
    assert len(ASSIGNED_ARCHS) == 10
    assert len(ALL_ARCHS) == 11


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_counts(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    exp, rtol = EXPECTED_PARAMS[arch]
    assert abs(n - exp) / exp < rtol, \
        f"{arch}: {n/1e9:.2f}B params, expected {exp/1e9:.1f}B ±{rtol:%}"


def test_active_params_moe():
    ds = get_config("deepseek-v3-671b")
    act = ds.active_param_count()
    assert abs(act - 37e9) / 37e9 < 0.35, f"{act/1e9:.1f}B active"
    gr = get_config("granite-moe-3b-a800m")
    assert gr.active_param_count() < gr.param_count() * 0.5


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_configs_reduced(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 4
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4


def test_shape_matrix():
    """32 valid pairs; skips documented in DESIGN.md §4."""
    runs = skips = 0
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for s in SHAPES.values():
            ok, reason = applicable(cfg, s)
            runs += ok
            skips += not ok
            if not ok:
                assert reason
    assert runs == 32 and skips == 8


def test_long_decode_archs():
    for arch, expect in [("rwkv6-7b", True), ("zamba2-2.7b", True),
                         ("gemma3-12b", True), ("qwen2.5-14b", False),
                         ("hubert-xlarge", False)]:
        cfg = get_config(arch)
        ok, _ = applicable(cfg, get_shape("long_500k"))
        assert ok == expect, arch


def test_engine_config_invariant():
    e = EngineConfig(train_batch_size=32, gradient_accumulation_steps=2)
    assert e.derived_micro_batch(dp_world=4) == 4
    e.validate(4)
    with pytest.raises(ValueError):
        EngineConfig(train_batch_size=30,
                     gradient_accumulation_steps=4).validate(4)


def test_gemma_layer_windows():
    cfg = get_config("gemma3-12b")
    w = cfg.layer_windows()
    assert len(w) == 48
    assert w.count(0) == 8                      # 1 global per 6
    assert all(x in (0, 1024) for x in w)
    # pattern: 5 local then 1 global
    assert w[:6] == [1024] * 5 + [0]
