"""Multi-host correctness of the v2 elastic checkpoint format
(repro-elastic-ckpt/v2): simulated multi-process saves (per-process
staging + manifests, process-0 merge barrier + single commit), the
merge-validation invariants, the shard-overlap LAZY restore byte
accounting, and the fd-leak / gc-truthfulness regressions.

Multi-process runs are simulated with ``simulate_processes`` — the seam
patches the process index/count and the device→process mapping that the
save/restore paths consult, so one controller can produce genuine
per-process artifacts and merge them (see the ``multihost-ckpt`` CI job).
"""
import json
import os

import numpy as np
import pytest

import repro.checkpoint as ck
import repro.checkpoint.checkpoint as ck_mod
from conftest import run_subprocess


def _tiny_tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": rng.normal(size=(4, 6)).astype(np.float32),
            "b": rng.normal(size=(6,)).astype(np.float32),
            "step": np.int64(3)}


# ---------------------------------------------------------------------------
# simulated 2-process save: layout, merge, restore equality
# ---------------------------------------------------------------------------

def test_simulated_two_process_save_merges_and_restores(tmp_path):
    """p1 stages its (empty-on-one-device) partition, p0 stages its own,
    merges at the barrier, and commits ONE directory holding both
    per-process manifests + shard files and the merged manifest; a plain
    restore reproduces every leaf exactly."""
    tree = _tiny_tree()
    d = str(tmp_path)
    # process 0 runs the commit, so the simulated p1 must save first
    with ck.simulate_processes(1, 2):
        ck.save_checkpoint(d, 3, tree, retry=None)
        assert ck.list_steps(d) == []          # nothing committed yet
    with ck.simulate_processes(0, 2):
        ck.save_checkpoint(d, 3, tree, retry=None)
    assert ck.list_steps(d) == [3]

    sd = os.path.join(d, "step_00000003")
    names = sorted(os.listdir(sd))
    assert names == ["manifest-p00.json", "manifest-p01.json",
                     "manifest.json", "shards-p00.npz", "shards-p01.npz"]
    assert not any(n.endswith(".tmp") or ".tmp-p" in n
                   for n in os.listdir(d))     # staging fully consumed

    man = json.load(open(os.path.join(sd, "manifest.json")))
    assert man["format"] == ck_mod.FORMAT
    assert man["processes"] == 2
    # host leaves are owned by process 0 ONLY — exactly one shard each
    for key in ("w", "b", "step"):
        entries = man["leaves"][key]["shards"]
        assert len(entries) == 1, (key, entries)
        assert entries[0]["process"] == 0

    ck.verify_checkpoint(d, 3)
    out = ck.restore_checkpoint(d, 3, tree)
    for key in tree:
        assert np.array_equal(np.asarray(out[key]), tree[key]), key

    rep = ck.checkpoint_size_report(d, 3)
    assert rep["saved_bytes"] == rep["logical_bytes"], rep
    assert set(rep["per_process_bytes"]) == {0}
    assert set(ck.per_process_restore_bytes(d, 3)) == {0, 1}


def test_snapshot_host_leaves_owned_by_process_zero_only():
    """The duplicate-host-shard fix: only process 0 claims host/scalar
    leaves, so a multi-process save cannot write them twice."""
    tree = _tiny_tree()
    with ck.simulate_processes(0, 2):
        snap0 = ck_mod._snapshot(tree)
    with ck.simulate_processes(1, 2):
        snap1 = ck_mod._snapshot(tree)
    assert snap0["process"] == 0 and snap1["process"] == 1
    for key in tree:
        assert len(snap0["leaves"][key]["shards"]) == 1
        assert snap1["leaves"][key]["shards"] == []
    # leaf METADATA still recorded by every process (merge alignment)
    assert set(snap1["leaves"]) == set(snap0["leaves"])


# ---------------------------------------------------------------------------
# merge_manifests validation invariants
# ---------------------------------------------------------------------------

def _manifest(process, processes, leaves):
    return {"format": ck_mod.FORMAT, "step": 5, "process": process,
            "processes": processes, "mesh": None, "leaves": leaves}


def _leaf(entries, shape=(4,)):
    return {"dtype": "float32", "shape": list(shape), "spec": None,
            "shards": entries}


def _entry(process, index):
    return {"file": f"shards-p{process:02d}.npz", "key": "a0",
            "shape": [b - a for a, b in index], "index": index,
            "device": 0, "process": process, "crc32": 0}


def test_merge_rejects_duplicate_host_leaf_ownership():
    """Over-coverage (the saved_bytes == logical_bytes invariant): a host
    leaf written by BOTH processes is caught at the barrier, not at some
    later restore."""
    m0 = _manifest(0, 2, {"s": _leaf([_entry(0, [[0, 4]])])})
    m1 = _manifest(1, 2, {"s": _leaf([_entry(1, [[0, 4]])])})
    with pytest.raises(ValueError, match="duplicate/overlapping"):
        ck.merge_manifests([m0, m1])


def test_merge_rejects_lost_shard_coverage():
    m0 = _manifest(0, 2, {"s": _leaf([_entry(0, [[0, 2]])])})
    m1 = _manifest(1, 2, {"s": _leaf([])})
    with pytest.raises(ValueError, match="incomplete"):
        ck.merge_manifests([m0, m1])


def test_merge_rejects_missing_process_and_key_mismatch():
    m0 = _manifest(0, 2, {"s": _leaf([_entry(0, [[0, 4]])])})
    with pytest.raises(ValueError, match="declared 2"):
        ck.merge_manifests([m0])
    m1 = _manifest(1, 2, {"t": _leaf([])})
    with pytest.raises(KeyError, match="leaf keys disagree"):
        ck.merge_manifests([m0, m1])


def test_merge_barrier_times_out_naming_stragglers(tmp_path, monkeypatch):
    """Process 0 alone at the barrier: the save fails with
    CheckpointBarrierTimeout (NOT an OSError — the IO retry must not
    re-run the wait) and nothing is committed."""
    monkeypatch.setattr(ck_mod, "MERGE_BARRIER_TIMEOUT", 0.2)
    d = str(tmp_path)
    with ck.simulate_processes(0, 2):
        with pytest.raises(ck.CheckpointBarrierTimeout, match=r"\[1\]"):
            ck.save_checkpoint(d, 1, _tiny_tree(), retry=None)
    assert ck.list_steps(d) == []
    assert not isinstance(ck.CheckpointBarrierTimeout("x"), OSError)


# ---------------------------------------------------------------------------
# regression: NpzFile handles are closed deterministically
# ---------------------------------------------------------------------------

def test_npz_handles_closed_after_fallback_scan(tmp_path, monkeypatch):
    """A restore_latest_valid fallback over several corrupt steps opens
    many npz files; every handle must be CLOSED afterwards (numpy marks a
    closed NpzFile by zip=None) — the fd-leak fix."""
    d = str(tmp_path)
    tree = _tiny_tree()
    for step in (1, 2, 3):
        ck.save_checkpoint(d, step, tree, retry=None)
    for step in (2, 3):                  # corrupt the two newest
        shard = os.path.join(d, f"step_{step:08d}", "shards-p00.npz")
        with open(shard, "r+b") as f:
            f.seek(os.path.getsize(shard) // 2)
            f.write(b"\xde\xad\xbe\xef" * 4)

    opened = []
    real_load = np.load

    def tracking_load(*a, **kw):
        f = real_load(*a, **kw)
        opened.append(f)
        return f

    monkeypatch.setattr(ck_mod.np, "load", tracking_load)
    out, step = ck.restore_latest_valid(d, tree)
    assert step == 1
    assert np.array_equal(np.asarray(out["w"]), tree["w"])
    with pytest.raises(ck.CheckpointCorruptError):
        ck.verify_checkpoint(d, 3)
    assert opened, "tracking hook never saw an np.load"
    still_open = [f for f in opened if f.zip is not None]
    assert not still_open, f"{len(still_open)} NpzFile(s) left open"


# ---------------------------------------------------------------------------
# regression: gc_checkpoints reports only deletions that actually happened
# ---------------------------------------------------------------------------

def test_gc_excludes_failed_deletions_and_warns(tmp_path, monkeypatch,
                                                capsys):
    d = str(tmp_path)
    tree = _tiny_tree()
    for step in (1, 2, 3, 4):
        ck.save_checkpoint(d, step, tree, retry=None)

    real_rmtree = ck_mod.shutil.rmtree

    def failing_rmtree(path, *a, **kw):
        if path.endswith("step_00000002"):
            raise OSError("device or resource busy")
        return real_rmtree(path, *a, **kw)

    monkeypatch.setattr(ck_mod.shutil, "rmtree", failing_rmtree)
    deleted = ck.gc_checkpoints(d, 1)
    assert deleted == [1, 3]             # 2 failed, truthfully excluded
    assert ck.list_steps(d) == [2, 4]    # the failed step is still there
    warn = capsys.readouterr().out
    assert "failed to delete step 2" in warn


# ---------------------------------------------------------------------------
# full engine round trip: simulated 2-process save -> merge -> elastic
# restore at a different layout, plus the lazy read-bytes contract
# ---------------------------------------------------------------------------

_MH = r"""
import json, os, tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config, EngineConfig
from repro.core.engine import DistributedEngine
import repro.checkpoint as ck
from repro.checkpoint.checkpoint import _flatten
from repro.launch.specs import concrete_batch

CFG = get_smoke_config("vit-b16").replace(dtype="float32")

def make_engine(zero=0, pipe=1):
    if pipe > 1:
        mesh = jax.make_mesh((8 // pipe, pipe, 1), ("data", "pipe", "model"))
    else:
        mesh = jax.make_mesh((8, 1), ("data", "model"))
    ecfg = EngineConfig(train_batch_size=16, gradient_accumulation_steps=2,
                        zero_stage=zero, lr=1e-3, total_steps=10,
                        warmup_steps=1, pipeline_stages=pipe)
    return DistributedEngine(CFG, ecfg, mesh)

def run(eng, state, lo, hi):
    step = eng.jit_train_step(donate=False)
    losses = []
    with eng.mesh:
        for i in range(lo, hi):
            state, m = step(state, concrete_batch(CFG, 16, 16, seed=i))
            losses.append(float(m["loss"]))
    return state, losses

def assert_bitwise(a, b):
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree_util.tree_flatten_with_path(b)[0]
    for (pa, xa), (_, xb) in zip(fa, fb):
        assert np.array_equal(np.asarray(jax.device_get(xa)),
                              np.asarray(jax.device_get(xb))), pa
"""


def test_two_process_save_cross_layout_restore_and_lazy_reads():
    """ZeRO-3 dp=8 state saved as a SIMULATED 2-process run (4 devices per
    process): the commit holds two distinct shard files + per-process
    manifests + the merged manifest; restore into dp4 x pp2 is bitwise on
    params/opt and the resumed trajectory matches the uninterrupted one
    to 1e-5; and the per-process lazy restore reads strictly fewer shard
    entries/bytes than the logical whole — the O(local partition)
    contract, counter-asserted."""
    out = run_subprocess(_MH + r"""
src = make_engine(zero=3)
s2, _ = run(src, src.init_state(seed=0), 0, 2)
d = tempfile.mkdtemp()
# process 0 commits at the merge barrier, so the simulated p1 saves first
with ck.simulate_processes(1, 2):
    ck.save_checkpoint(d, 2, s2)
    assert ck.list_steps(d) == []
with ck.simulate_processes(0, 2):
    ck.save_checkpoint(d, 2, s2)
assert ck.list_steps(d) == [2]

sd = os.path.join(d, "step_00000002")
names = sorted(os.listdir(sd))
assert names == ["manifest-p00.json", "manifest-p01.json",
                 "manifest.json", "shards-p00.npz", "shards-p01.npz"], names
# both processes contributed real shard bytes (zero3 partitions over dp=8)
assert os.path.getsize(os.path.join(sd, "shards-p00.npz")) > 10000
assert os.path.getsize(os.path.join(sd, "shards-p01.npz")) > 10000
man = json.load(open(os.path.join(sd, "manifest.json")))
assert man["format"] == "repro-elastic-ckpt/v2" and man["processes"] == 2
files = {e["file"] for m in man["leaves"].values() for e in m["shards"]}
assert files == {"shards-p00.npz", "shards-p01.npz"}, files

rep = ck.checkpoint_size_report(d, 2)
assert rep["saved_bytes"] == rep["logical_bytes"], rep
assert set(rep["per_process_bytes"]) == {0, 1}, rep["per_process_bytes"]

_, ref = run(src, s2, 2, 5)                 # uninterrupted continuation

eng2 = make_engine(pipe=2)                  # different layout: dp4 x pp2
s2b = eng2.restore_state(d)
assert int(s2b.step) == 2
assert_bitwise(s2.params, s2b.params)
assert_bitwise(s2.opt_state, s2b.opt_state)
_, res = run(eng2, s2b, 2, 5)
for a, b in zip(ref, res):
    assert abs(a - b) < 1e-5, (ref, res)

# lazy-restore contract: per process, only intersecting shards are read
like = src.abstract_state()
shardings = src.state_shardings()
full = ck.restore_checkpoint(d, 2, like, shardings=None)
full_stats = ck.last_restore_stats()
assert full_stats.entries_read == full_stats.entries_total
full_items = dict(_flatten(full))
for p in (0, 1):
    with ck.simulate_processes(p, 2):
        plan, stats = ck.restore_local_shards(d, 2, like, shardings)
    assert stats.entries_read < stats.entries_total, stats
    assert stats.read_bytes < 0.8 * stats.logical_bytes, stats
    assert stats.partition_bytes < 0.8 * stats.logical_bytes, stats
    n_blocks = 0
    for key, items in plan.items():
        for dev_id, rkey, block in items:
            sl = tuple(slice(a, b) for a, b in rkey)
            want = np.asarray(full_items[key])[sl]
            assert np.array_equal(block, want), (key, dev_id, rkey)
            n_blocks += 1
    assert n_blocks > 0
print("OK", ref)
""", devices=8, timeout=900)
    assert "OK" in out
