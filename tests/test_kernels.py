"""Per-kernel shape/dtype sweeps vs the ref.py pure-jnp oracles
(interpret=True executes kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import fused_rmsnorm, wkv6
from repro.kernels.ref import ref_attention, ref_rmsnorm, ref_wkv6

KEY = jax.random.PRNGKey(42)


@pytest.mark.parametrize("b,h,kh,s,d", [
    (1, 4, 4, 128, 64), (2, 8, 2, 256, 64), (1, 4, 1, 128, 128),
    (2, 2, 2, 64, 32),
    (1, 4, 2, 100, 32),   # ragged tail: s % block != 0 (OOB blocks masked)
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64),
                                           (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, h, kh, s, d, causal, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, kh, s, d), dtype)
    v = jax.random.normal(ks[2], (b, kh, s, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    ref = ref_attention(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("b,s,h,p,chunk", [
    (1, 64, 2, 32, 16), (2, 128, 4, 64, 32), (1, 96, 2, 64, 32),
    (2, 57, 3, 32, 16),   # ragged: pads internally
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_sweep(b, s, h, p, chunk, dtype):
    ks = jax.random.split(KEY, 6)
    r, k, v = (jax.random.normal(ks[i], (b, s, h, p), dtype)
               for i in range(3))
    wlog = -jnp.exp(jax.random.normal(ks[3], (b, s, h, p)) - 0.5)
    u = 0.3 * jax.random.normal(ks[4], (h, p))
    s0 = 0.1 * jax.random.normal(ks[5], (b, h, p, p))
    o, se = wkv6(r, k, v, wlog.astype(dtype), u, s0, chunk=chunk,
                 interpret=True)
    oref, seref = ref_wkv6(r, k, v, wlog, u, s0)
    tol = 5e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref), atol=tol)
    np.testing.assert_allclose(np.asarray(se), np.asarray(seref), atol=tol)


def test_wkv6_strong_decay_no_overflow():
    """The pairwise-decay formulation must survive extreme decay (the
    factored r·e^L / k·e^-L form overflows fp32 here)."""
    b, s, h, p = 1, 128, 2, 32
    ks = jax.random.split(KEY, 3)
    r, k, v = (jax.random.normal(ks[i], (b, s, h, p)) for i in range(3))
    wlog = jnp.full((b, s, h, p), -8.0)    # decay 3e-4/step, L_end = -1024
    u = jnp.zeros((h, p))
    s0 = jnp.zeros((b, h, p, p))
    o, se = wkv6(r, k, v, wlog, u, s0, chunk=32, interpret=True)
    assert np.isfinite(np.asarray(o)).all()
    oref, _ = ref_wkv6(r, k, v, wlog, u, s0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref), atol=1e-4)


@pytest.mark.parametrize("shape", [(64, 256), (3, 37, 128), (2, 2, 2, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    sc = jax.random.normal(jax.random.PRNGKey(1), shape[-1:])
    out = fused_rmsnorm(x, sc, interpret=True)
    ref = ref_rmsnorm(x, sc)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_separate_value_dim():
    """MLA: v head-dim differs from qk head-dim."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 48))
    k = jax.random.normal(ks[1], (1, 4, 128, 48))
    v = jax.random.normal(ks[2], (1, 4, 128, 32))
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    ref = ref_attention(q, k, v, causal=True)
    assert out.shape == (1, 4, 128, 32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
