"""The paper's §V future work: "evaluate each ZeRO stage to measure memory
savings and overhead".

Default mode measures it from the compiled dry-run: per-device argument
bytes (params + opt state + inputs) for ZeRO 0-3 on the 256-chip mesh.

``--ckpt-sizes`` measures the ELASTIC CHECKPOINT footprint instead (the
CI artifact next to the resume-parity check): per stage, a subprocess with
8 host devices trains one step of the smoke ViT, saves the full TrainState
shard-locally, and reports total bytes plus the max bytes any one device
owns — the per-rank write cost a multi-host run would pay. It then
repeats the save as a SIMULATED 2-process run (the v2 merge-barrier
protocol: per-process staging + manifests, process-0 merge/commit) and
reports the max per-process restore bytes from the merged manifest — what
the lazy shard-overlap restore would read on the worse host. ZeRO > 0
shrinks the max-per-device and restore/proc columns (optimizer state, and
at stage 3 the params, spread over dp) while the total stays at logical
size — the no-hidden-all-gather invariant of repro.checkpoint.
"""
import argparse
import json
import os
import subprocess
import sys

_CKPT_CHILD = r"""
import json, sys, tempfile
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config, EngineConfig
from repro.core.engine import DistributedEngine
from repro.checkpoint import checkpoint_size_report
from repro.launch.mesh import make_local_mesh
from repro.launch.specs import concrete_batch

zero = int(sys.argv[1])
cfg = get_smoke_config("vit-b16").replace(dtype="float32")
mesh = make_local_mesh()
eng = DistributedEngine(cfg, EngineConfig(
    train_batch_size=8, zero_stage=zero, total_steps=10, warmup_steps=1),
    mesh)
state = eng.init_state(seed=0)
with mesh:
    state, _ = eng.jit_train_step(donate=False)(
        state, concrete_batch(cfg, 8, 16, seed=0))
d = tempfile.mkdtemp()
eng.save_state(d, state)
rep = checkpoint_size_report(d, 1)
# repeat as a simulated 2-process save (merge-barrier commit) and account
# what the lazy restore would read per host from the merged manifest
import repro.checkpoint as ck
d2 = tempfile.mkdtemp()
step = int(jax.device_get(state.step))
with ck.simulate_processes(1, 2):       # process 0 commits, so it saves last
    ck.save_checkpoint(d2, step, state)
with ck.simulate_processes(0, 2):
    ck.save_checkpoint(d2, step, state)
rep2 = checkpoint_size_report(d2, step)
assert rep2["saved_bytes"] == rep2["logical_bytes"]
restore = ck.per_process_restore_bytes(d2, step)
print("CKPT_JSON " + json.dumps({
    "zero": zero, "logical": rep["logical_bytes"],
    "saved": rep["saved_bytes"],
    "max_dev": max(rep["per_device_bytes"].values()),
    "devices": len(rep["per_device_bytes"]),
    "files": sum(rep["file_bytes"].values()),
    "restore_proc": max(restore.values()),
    "sim_processes": len(restore)}))
"""


def ckpt_sizes(devices: int = 8):
    root = os.path.join(os.path.dirname(__file__), "..")
    sys.path[:0] = [root, os.path.join(root, "src")]
    from benchmarks.common import child_env

    print(f"Checkpoint size per ZeRO stage — vit-b16 smoke TrainState, "
          f"{devices} host devices (shard-local elastic v2 format; "
          f"restore/proc from a simulated 2-process merged manifest)\n")
    print(f"{'stage':>6s} {'logical MiB':>12s} {'saved MiB':>10s} "
          f"{'max/dev MiB':>12s} {'owning devs':>12s} "
          f"{'restore/proc MiB':>17s}")
    ok = True
    for stage in (0, 1, 2, 3):
        r = subprocess.run(
            [sys.executable, "-c", _CKPT_CHILD, str(stage)],
            capture_output=True, text=True, timeout=1200,
            env=child_env(devices))
        if r.returncode != 0:
            print(f"{stage:6d}  FAIL: {r.stderr[-200:]}")
            ok = False
            continue
        rec = json.loads(next(
            ln for ln in r.stdout.splitlines()
            if ln.startswith("CKPT_JSON "))[len("CKPT_JSON "):])
        mib = 2 ** 20
        print(f"{stage:6d} {rec['logical']/mib:12.2f} "
              f"{rec['saved']/mib:10.2f} {rec['max_dev']/mib:12.2f} "
              f"{rec['devices']:12d} {rec['restore_proc']/mib:17.2f}")
        assert rec["saved"] == rec["logical"], \
            f"stage {stage}: saved {rec['saved']} != logical " \
            f"{rec['logical']} (replica written twice or shard missing)"
    if not ok:
        sys.exit(1)


def dryrun_table(arch: str, shape: str):
    # 512 host devices MUST be set before any jax-importing import (jax
    # locks the device count on first init; the dry-run contract)
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.launch.dryrun import run_pair

    print(f"ZeRO memory table — {arch} x {shape}, 256 chips "
          "(16 dp x 16 tp)\n")
    print(f"{'stage':>6s} {'args GiB/dev':>14s} {'peak GiB/dev':>14s} "
          f"{'coll GB/step':>14s} {'bound s':>10s}")
    for stage in (0, 1, 2, 3):
        try:
            rec = run_pair(arch, shape, zero=stage, verbose=False,
                           tag=f"zero{stage}")
        except Exception as e:  # noqa: BLE001 — stage 0 may OOM-by-design
            print(f"{stage:6d}  FAIL: {type(e).__name__}: {str(e)[:70]}")
            continue
        if rec["status"] != "ok":
            print(f"{stage:6d}  {rec['status']}: {rec.get('error','')[:70]}")
            continue
        coll = sum(rec["collectives"].values()) / 1e9
        print(f"{stage:6d} {rec['argument_bytes_per_dev']/2**30:14.2f} "
              f"{rec['peak_bytes_per_dev']/2**30:14.2f} {coll:14.1f} "
              f"{rec['roofline']['bound_step_s']:10.1f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--ckpt-sizes", action="store_true",
                    help="measure shard-local checkpoint bytes per ZeRO "
                         "stage instead of the compiled dry-run table")
    args = ap.parse_args()
    if args.ckpt_sizes:
        ckpt_sizes()
    else:
        dryrun_table(args.arch, args.shape)


if __name__ == "__main__":
    main()
