import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The paper's §V future work: "evaluate each ZeRO stage to measure memory
# savings and overhead". This measures it from the compiled dry-run:
# per-device argument bytes (params + opt state + inputs) for ZeRO 0-3.

import argparse   # noqa: E402
import sys        # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.dryrun import run_pair  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()

    print(f"ZeRO memory table — {args.arch} x {args.shape}, 256 chips "
          "(16 dp x 16 tp)\n")
    print(f"{'stage':>6s} {'args GiB/dev':>14s} {'peak GiB/dev':>14s} "
          f"{'coll GB/step':>14s} {'bound s':>10s}")
    for stage in (0, 1, 2, 3):
        try:
            rec = run_pair(args.arch, args.shape, zero=stage, verbose=False,
                           tag=f"zero{stage}")
        except Exception as e:  # noqa: BLE001 — stage 0 may OOM-by-design
            print(f"{stage:6d}  FAIL: {type(e).__name__}: {str(e)[:70]}")
            continue
        if rec["status"] != "ok":
            print(f"{stage:6d}  {rec['status']}: {rec.get('error','')[:70]}")
            continue
        coll = sum(rec["collectives"].values()) / 1e9
        print(f"{stage:6d} {rec['argument_bytes_per_dev']/2**30:14.2f} "
              f"{rec['peak_bytes_per_dev']/2**30:14.2f} {coll:14.1f} "
              f"{rec['roofline']['bound_step_s']:10.1f}")


if __name__ == "__main__":
    main()
