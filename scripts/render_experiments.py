"""Render EXPERIMENTS.md §Dry-run/§Roofline tables from results/*.jsonl."""
from __future__ import annotations

import json
import os
import sys

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load(fname):
    recs = {}
    path = os.path.join(RESULTS, fname)
    if not os.path.exists(path):
        return recs
    for line in open(path):
        r = json.loads(line)
        recs[(r["arch"], r["shape"])] = r
    return recs


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def roofline_table(recs):
    hdr = ("| arch | shape | params | mem/dev GiB | compute s | memory s | "
           "collective s | dominant | MODEL/HLO flops | coll GB (ar/ag/rs/a2a) |")
    sep = "|" + "---|" * 10
    rows = [hdr, sep]
    for (arch, shape), r in sorted(recs.items()):
        if r["status"] == "skip":
            rows.append(f"| {arch} | {shape} | — | — | — | — | — | "
                        f"SKIP | — | {r['reason'][:48]} |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {arch} | {shape} | — | — | — | — | — | FAIL | — "
                        f"| {r.get('error','')[:48]} |")
            continue
        rl = r["roofline"]
        c = r["collectives"]
        coll = (f"{c['all-reduce']/1e9:.1f}/{c['all-gather']/1e9:.1f}/"
                f"{c['reduce-scatter']/1e9:.1f}/{c['all-to-all']/1e9:.1f}")
        rows.append(
            f"| {arch} | {shape} | {r['params']/1e9:.1f}B "
            f"| {fmt_bytes(r['peak_bytes_per_dev'])} "
            f"| {rl['compute_s']:.3f} | {rl['memory_s']:.3f} "
            f"| {rl['collective_s']:.3f} | **{rl['dominant'][:-2]}** "
            f"| {rl['useful_flops_frac']:.2f} | {coll} |")
    return "\n".join(rows)


def main():
    single = load("baseline_singlepod.jsonl")
    multi = load("baseline_multipod.jsonl")
    print("### Single-pod (16x16 = 256 chips) baseline roofline\n")
    print(roofline_table(single))
    print("\n### Multi-pod (2x16x16 = 512 chips) lowering proof\n")
    if multi:
        n_ok = sum(r["status"] == "ok" for r in multi.values())
        n_skip = sum(r["status"] == "skip" for r in multi.values())
        n_fail = len(multi) - n_ok - n_skip
        print(f"{n_ok} pairs lowered+compiled on the (pod,data,model) mesh, "
              f"{n_skip} documented skips, {n_fail} failures.\n")
        print(roofline_table(multi))


if __name__ == "__main__":
    sys.exit(main())
