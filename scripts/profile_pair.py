import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import sys       # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.dryrun import engine_for  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import input_specs  # noqa: E402


def main():
    ap = argparse.ArgumentParser(
        description="dry-run profiler: top HBM/flops contributors")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--by", default="bytes", choices=["bytes", "flops"])
    ap.add_argument("--n", type=int, default=25)
    ap.add_argument("--zero", type=int, default=None)
    ap.add_argument("--seq-parallel", default=None)
    ap.add_argument("--moe-impl", default=None)
    ap.add_argument("--bf16-gather", action="store_true")
    ap.add_argument("--embed", default=None)
    args = ap.parse_args()

    mesh = make_production_mesh()
    eng, cfg, shape = engine_for(args.arch, args.shape, mesh,
                                 zero=args.zero,
                                 seq_parallel=args.seq_parallel)
    if args.moe_impl:
        eng.cfg = cfg = cfg.replace(moe_impl=args.moe_impl)
    if args.bf16_gather:
        eng.ecfg = eng.ecfg.replace(cast_params_bf16=True)
    if args.embed:
        eng.ecfg = eng.ecfg.replace(embed_sharding=args.embed)

    if shape.kind == "train":
        lowered = eng.lower_train(input_specs(cfg, shape))
    elif shape.kind == "prefill":
        lowered = eng.lower_prefill(input_specs(cfg, shape))
    else:
        lowered = eng.lower_decode(shape.global_batch, shape.seq_len)
    hlo = lowered.compile().as_text()
    totals = hlo_analysis.analyze(hlo)
    print(f"flops/dev={totals.flops:.3e}  hbm/dev={totals.hbm_bytes:.3e}  "
          f"coll={ {k: f'{v/1e9:.2f}GB' for k, v in totals.coll.items()} }")
    print(f"\ntop {args.n} by {args.by}:")
    for score, mult, comp, line in hlo_analysis.top_contributors(
            hlo, n=args.n, by=args.by):
        unit = score / 1e9
        print(f"  {unit:10.2f}G x{mult:6.0f}  [{comp[:40]:40s}] {line[:110]}")


if __name__ == "__main__":
    main()
