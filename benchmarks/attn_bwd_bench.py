"""Flash-attention fwd+bwd micro-benchmarks (interpret mode on CPU —
*relative* timings; the derived column carries the gradient max-error vs
the pure-jnp ref_attention oracle, which is the deploy gate for the
custom-VJP training hot path)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import ref_attention

CASES = [
    ("causal", True, 0),
    ("vit_bidir", False, 0),       # the paper's ViT encoder configuration
    ("window256", True, 256),
]


def _grad_fn(attn):
    def loss(q, k, v):
        return jnp.sum(attn(q, k, v).astype(jnp.float32))
    return jax.jit(jax.grad(loss, argnums=(0, 1, 2)))


def _max_err(ga, gb):
    return max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32))))
               for a, b in zip(ga, gb))


def bench_flash_fwd_bwd(rows):
    key = jax.random.PRNGKey(3)
    b, h, kh, s, d = 1, 4, 2, 512, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, kh, s, d))
    v = jax.random.normal(ks[2], (b, kh, s, d))

    for name, causal, window in CASES:
        flash = functools.partial(flash_attention, causal=causal,
                                  window=window, block_q=128, block_k=128,
                                  interpret=True)
        ref = functools.partial(ref_attention, causal=causal, window=window)
        f_fwd = jax.jit(lambda q, k, v, _f=flash: _f(q, k, v))
        g_flash = _grad_fn(flash)
        g_ref = _grad_fn(ref)
        t_fwd = time_fn(f_fwd, q, k, v, iters=3, warmup=1)
        t_bwd = time_fn(g_flash, q, k, v, iters=3, warmup=1)
        err = _max_err(g_flash(q, k, v), g_ref(q, k, v))
        emit(rows, f"flash_fwd_{name}_s512", t_fwd * 1e6, "pallas_interp")
        emit(rows, f"flash_fwdbwd_{name}_s512", t_bwd * 1e6,
             f"max_grad_err={err:.1e};oracle=ref_attention")


ALL = [bench_flash_fwd_bwd]
