"""Scaling suite: the paper's strong-scaling experiment as a tracked
artifact — dp x pp layout sweep of the ViT-B/16 smoke workload on host
platform devices, emitting per-layout step time, 1F1B bubble fraction, and
per-collective bytes from the trip-count-aware HLO analyzer.

Each layout runs in a subprocess (host device count is fixed at jax init,
so an in-process sweep cannot change it); the child measures a jitted
train step and analyzes its optimized HLO, then prints one JSON line this
parent turns into ``name,us_per_call,derived`` rows for
``BENCH_scaling.json`` (the second trajectory artifact next to
``BENCH_kernels.json``).

CPU-host step times are *relative* numbers — the derived column's
collective-bytes and bubble-fraction terms are the layout-comparison
signal (they are substrate-independent).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

# dp x pp over 8 host devices; (8, 1) is the dp-only baseline
LAYOUTS = ((8, 1), (4, 2), (2, 4))
DEVICES = 8
ACCUM = 4
BATCH = 32
STEPS = 2

_CHILD = r"""
import json, sys, time
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config, EngineConfig
from repro.core.engine import DistributedEngine
from repro.core.pipeline import bubble_fraction
from repro.launch import hlo_analysis
from repro.launch.mesh import make_local_mesh
from repro.launch.specs import concrete_batch

dp, pp, batch, accum, steps = (int(a) for a in sys.argv[1:6])
cfg = get_smoke_config("vit-b16").replace(dtype="float32", num_layers=4)
mesh = make_local_mesh(model=1, pipe=pp)
ecfg = EngineConfig(train_batch_size=batch, gradient_accumulation_steps=accum,
                    total_steps=10, warmup_steps=1, pipeline_stages=pp)
eng = DistributedEngine(cfg, ecfg, mesh)
params, opt = eng.init(seed=0)
step = eng.jit_train_step(donate=False)
b = concrete_batch(cfg, batch, 32, seed=0)
with mesh:
    step(params, opt, b, jnp.int32(0))[2]["loss"].block_until_ready()  # warmup
    t0 = time.time()
    for i in range(steps):
        out = step(params, opt, b, jnp.int32(i))
    jax.block_until_ready(out)
    dt = (time.time() - t0) / steps
    # reuse the already-warm jitted step: hits the compile cache instead of
    # eng.lower_train's fresh wrapper (which would recompile from scratch)
    hlo = step.lower(params, opt, b, jnp.int32(0)).compile().as_text()
totals = hlo_analysis.analyze(hlo)
print("SCALING_JSON " + json.dumps({
    "dp": dp, "pp": pp, "step_us": dt * 1e6,
    "bubble_frac": bubble_fraction(accum, pp),
    "coll": {k: v for k, v in totals.coll.items() if v},
    "coll_bytes": totals.coll_bytes,
    "loss": float(out[2]["loss"]),
}))
"""


def _run_layout(dp: int, pp: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={DEVICES}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    r = subprocess.run(
        [sys.executable, "-c", _CHILD, str(dp), str(pp), str(BATCH),
         str(ACCUM), str(STEPS)],
        capture_output=True, text=True, timeout=1200, env=env)
    if r.returncode != 0:
        raise RuntimeError(
            f"scaling child dp={dp} pp={pp} failed:\n{r.stderr[-2000:]}")
    for line in r.stdout.splitlines():
        if line.startswith("SCALING_JSON "):
            return json.loads(line[len("SCALING_JSON "):])
    raise RuntimeError(f"no SCALING_JSON line in child output:\n{r.stdout}")


def bench_scaling_layouts(rows):
    """One row per dp x pp layout: measured step time; derived carries the
    analytic 1F1B bubble fraction and the HLO collective-byte breakdown."""
    results = [_run_layout(dp, pp) for dp, pp in LAYOUTS]
    base = results[0]["step_us"]
    for res in results:
        coll = ";".join(f"{k.replace('-', '_')}={v:.3e}"
                        for k, v in sorted(res["coll"].items()))
        rows.append(
            f"scaling_dp{res['dp']}_pp{res['pp']},{res['step_us']:.2f},"
            f"bubble_frac={res['bubble_frac']:.3f};"
            f"coll_bytes={res['coll_bytes']:.3e};"
            f"rel_step={res['step_us'] / base:.2f};{coll}")


ALL = [bench_scaling_layouts]
