"""Scaling suite: the paper's strong-scaling experiment as a tracked
artifact — dp x pp (x interleave) layout sweep of the ViT-B/16 smoke
workload on host platform devices, emitting per-layout step time,
simulated 1F1B bubble fraction, per-collective bytes from the
trip-count-aware HLO analyzer, and the pp_peak_mem_M{4,8,16} peak-memory
axis (compiled temp bytes of the staged pipeline backward vs microbatch
count — flat in M is the memory-boundedness contract CI gates on).

Each layout runs in a subprocess (host device count is fixed at jax init,
so an in-process sweep cannot change it); the child measures a jitted
train step and analyzes its optimized HLO, then prints one JSON line this
parent turns into ``name,us_per_call,derived`` rows for
``BENCH_scaling.json`` (the second trajectory artifact next to
``BENCH_kernels.json``).

CPU-host step times are *relative* numbers — the derived column's
collective-bytes and bubble-fraction terms are the layout-comparison
signal (they are substrate-independent).
"""
from __future__ import annotations

import json
import subprocess
import sys

# dp x pp x interleave over 8 host devices; (8, 1, 1) is the dp-only
# baseline and (4, 2, 2) the Megatron interleaved layout (v=2 virtual
# chunks per pipe device)
LAYOUTS = ((8, 1, 1), (4, 2, 1), (2, 4, 1), (4, 2, 2))
DEVICES = 8
ACCUM = 4
BATCH = 32
STEPS = 2

_CHILD = r"""
import json, sys, time
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config, EngineConfig
from repro.core.engine import DistributedEngine
from repro.core.pipeline import simulated_bubble_fraction
from repro.launch import hlo_analysis
from repro.launch.mesh import make_local_mesh
from repro.launch.specs import concrete_batch

dp, pp, v, batch, accum, steps = (int(a) for a in sys.argv[1:7])
cfg = get_smoke_config("vit-b16").replace(dtype="float32", num_layers=4)
mesh = make_local_mesh(model=1, pipe=pp)
ecfg = EngineConfig(train_batch_size=batch, gradient_accumulation_steps=accum,
                    total_steps=10, warmup_steps=1, pipeline_stages=pp,
                    pipeline_interleave=v)
eng = DistributedEngine(cfg, ecfg, mesh)
state = eng.init_state(seed=0)
step = eng.jit_train_step(donate=False)
b = concrete_batch(cfg, batch, 32, seed=0)
with mesh:
    step(state, b)[1]["loss"].block_until_ready()  # warmup
    t0 = time.time()
    for i in range(steps):
        out = step(state, b)
    jax.block_until_ready(out)
    dt = (time.time() - t0) / steps
    # reuse the already-warm jitted step: hits the compile cache instead of
    # eng.lower_train's fresh wrapper (which would recompile from scratch)
    hlo = step.lower(state, b).compile().as_text()
totals = hlo_analysis.analyze(hlo)
print("SCALING_JSON " + json.dumps({
    "dp": dp, "pp": pp, "v": v, "step_us": dt * 1e6,
    # executed-schedule bubble read off the simulator (== analytic
    # (S-1)/(v*M+S-1) for both flat and interleaved schedules)
    "bubble_frac": simulated_bubble_fraction(accum, pp, v) if pp > 1
    else 0.0,
    "coll": {k: v_ for k, v_ in totals.coll.items() if v_},
    "coll_bytes": totals.coll_bytes,
    "loss": float(out[1]["loss"]),
}))
"""


def _run_layout(dp: int, pp: int, v: int) -> dict:
    from benchmarks.common import child_env
    r = subprocess.run(
        [sys.executable, "-c", _CHILD, str(dp), str(pp), str(v), str(BATCH),
         str(ACCUM), str(STEPS)],
        capture_output=True, text=True, timeout=1800,
        env=child_env(DEVICES))
    if r.returncode != 0:
        raise RuntimeError(
            f"scaling child dp={dp} pp={pp} v={v} failed:"
            f"\n{r.stderr[-2000:]}")
    for line in r.stdout.splitlines():
        if line.startswith("SCALING_JSON "):
            return json.loads(line[len("SCALING_JSON "):])
    raise RuntimeError(f"no SCALING_JSON line in child output:\n{r.stdout}")


def bench_scaling_layouts(rows):
    """One row per dp x pp (x interleave) layout: measured step time;
    derived carries the simulated 1F1B bubble fraction and the HLO
    collective-byte breakdown."""
    results = [_run_layout(dp, pp, v) for dp, pp, v in LAYOUTS]
    base = results[0]["step_us"]
    for res in results:
        coll = ";".join(f"{k.replace('-', '_')}={v:.3e}"
                        for k, v in sorted(res["coll"].items()))
        name = f"scaling_dp{res['dp']}_pp{res['pp']}" + (
            f"_v{res['v']}" if res["v"] > 1 else "")
        rows.append(
            f"{name},{res['step_us']:.2f},"
            f"bubble_frac={res['bubble_frac']:.3f};"
            f"coll_bytes={res['coll_bytes']:.3e};"
            f"rel_step={res['step_us'] / base:.2f};{coll}")


# peak-memory-vs-M axis: compiled temp-buffer bytes (XLA buffer
# assignment = peak simultaneous liveness) of the staged 1F1B
# value-and-grad at fixed stages S while the microbatch COUNT M grows
# with per-microbatch size held constant. The manual per-chunk VJP path
# keeps only O(S) residual sets live, so the activation component is
# flat in M — the old AD-through-schedule path grew ~linearly (all M
# residual sets live through the backward). The CI memory-regression
# gate fails if the M=16/M=4 ratio exceeds PEAK_MEM_GATE.
PEAK_MEM_MICROS = (4, 8, 16)
PEAK_MEM_STAGES = 2
PEAK_MEM_MB = 16          # per-microbatch batch size (activations dominate)
PEAK_MEM_GATE = 2.2       # linear growth would be ~4x over M=4 -> 16

_PEAK_MEM_CHILD = r"""
import json, sys
import jax
from repro.configs import get_smoke_config
from repro.core import pipeline
from repro.launch.specs import concrete_batch
from repro.models import transformer as model

stages, mb = int(sys.argv[1]), int(sys.argv[2])
micros = [int(a) for a in sys.argv[3:]]
cfg = get_smoke_config("vit-b16").replace(dtype="float32", num_layers=4)
params = model.init_params(cfg, jax.random.PRNGKey(0))
out = {}
for M in micros:
    batch = concrete_batch(cfg, mb * M, 32, seed=0)
    compiled = jax.jit(lambda p, b: pipeline.pipelined_value_and_grad(
        cfg, p, b, stages=stages, num_micro=M, pipe_axis=None)).lower(
        params, batch).compile()
    ma = compiled.memory_analysis()
    if ma is None or not getattr(ma, "temp_size_in_bytes", 0):
        print("PEAK_MEM_JSON " + json.dumps({"unsupported": True}))
        sys.exit(0)
    out[str(M)] = int(ma.temp_size_in_bytes)
print("PEAK_MEM_JSON " + json.dumps(out))
"""


def bench_pp_peak_mem(rows):
    """pp_peak_mem_M{4,8,16} rows: compiled peak temp bytes of the staged
    pipeline backward at fixed S=2 and fixed per-microbatch size — the
    memory-boundedness trajectory (flat-in-M is the acceptance bar)."""
    from benchmarks.common import child_env
    r = subprocess.run(
        [sys.executable, "-c", _PEAK_MEM_CHILD, str(PEAK_MEM_STAGES),
         str(PEAK_MEM_MB)] + [str(m) for m in PEAK_MEM_MICROS],
        capture_output=True, text=True, timeout=1800, env=child_env(1))
    if r.returncode != 0:
        raise RuntimeError(f"peak-mem bench failed:\n{r.stderr[-2000:]}")
    line = next(ln for ln in r.stdout.splitlines()
                if ln.startswith("PEAK_MEM_JSON "))
    res = json.loads(line[len("PEAK_MEM_JSON "):])
    if res.get("unsupported"):
        rows.append("pp_peak_mem_unsupported,0.00,"
                    "compiled memory_analysis unavailable on this backend")
        return
    base = res[str(PEAK_MEM_MICROS[0])]
    for m in PEAK_MEM_MICROS:
        b = res[str(m)]
        rows.append(
            f"pp_peak_mem_M{m},{float(b):.2f},"
            f"peak_temp_mb={b / 1e6:.2f};ratio_vs_M4={b / base:.3f};"
            f"stages={PEAK_MEM_STAGES};micro_batch={PEAK_MEM_MB};"
            f"gate={PEAK_MEM_GATE}")


# host-data-path ablation: synchronous synth+device_put per step vs the
# one-deep background Prefetcher (data/pipeline.py) overlapping both with
# the running compiled step. Large batch so host synthesis is non-trivial.
_PREFETCH_CHILD = r"""
import json, sys, time
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config, EngineConfig
from repro.core import sharding as shd
from repro.core.engine import DistributedEngine
from repro.data import DATASETS, DataPipeline
from repro.launch.mesh import make_local_mesh

batch, steps = int(sys.argv[1]), int(sys.argv[2])
cfg = get_smoke_config("vit-b16").replace(dtype="float32")
mesh = make_local_mesh()
ecfg = EngineConfig(train_batch_size=batch, total_steps=100, warmup_steps=1)
eng = DistributedEngine(cfg, ecfg, mesh)
pipe = DataPipeline(kind="image", global_batch=batch,
                    dataset=DATASETS["cifar10"], resolution=cfg.image_size)
state = eng.init_state(seed=0)
step = eng.jit_train_step(donate=False)
bshard = shd.named(mesh, shd.batch_specs(cfg, pipe.batch_shapes(), mesh))

def run_sync():
    s, e, i = state, 0, 0
    for _ in range(steps):
        b = pipe.device_put(pipe.batch_at(e, i), bshard)
        s, m = step(s, b)
        e, i = pipe.next_cursor(e, i)
    return m

def run_prefetch():
    s = state
    with pipe.prefetch(0, 0, shardings=bshard) as pf:
        for _ in range(steps):
            _, b, _ = next(pf)
            s, m = step(s, b)
    return m

with mesh:
    out = {}
    for name, fn in (("off", run_sync), ("on", run_prefetch)):
        fn()  # warmup (compile + thread spin-up)
        t0 = time.time()
        jax.block_until_ready(fn()["loss"])
        out[name] = (time.time() - t0) / steps * 1e6
print("PREFETCH_JSON " + json.dumps(out))
"""


def bench_data_prefetch(rows):
    """prefetch_off vs prefetch_on step time for the vit smoke workload —
    the satellite's host-data-overlap delta (CPU-relative numbers; the
    overlap fraction is the signal)."""
    from benchmarks.common import child_env
    r = subprocess.run(
        [sys.executable, "-c", _PREFETCH_CHILD, "256", "8"],
        capture_output=True, text=True, timeout=1200,
        env=child_env(DEVICES))
    if r.returncode != 0:
        raise RuntimeError(f"prefetch bench failed:\n{r.stderr[-2000:]}")
    line = next(ln for ln in r.stdout.splitlines()
                if ln.startswith("PREFETCH_JSON "))
    res = json.loads(line[len("PREFETCH_JSON "):])
    rows.append(f"prefetch_off,{res['off']:.2f},sync host synth+device_put")
    rows.append(
        f"prefetch_on,{res['on']:.2f},"
        f"rel_step={res['on'] / res['off']:.3f};one-deep background "
        f"prefetcher (data/pipeline.py)")


# anomaly-guard ablation: the in-jit finite checks + tree-wide select
# (core/engine.py) plus the host-side per-step step_ok readback, vs the
# unguarded step. The acceptance bar is <= 2% overhead (rel_step <= 1.02
# within CPU-timer noise) — the guard is always-on by default, so its
# cost IS the production step cost.
_GUARD_CHILD = r"""
import json, sys, time
import jax, numpy as np
from repro.configs import get_smoke_config, EngineConfig
from repro.core.engine import DistributedEngine
from repro.launch.mesh import make_local_mesh
from repro.launch.specs import concrete_batch

batch, steps = int(sys.argv[1]), int(sys.argv[2])
cfg = get_smoke_config("vit-b16").replace(dtype="float32")
mesh = make_local_mesh()
out = {}
for name, guard in (("off", False), ("on", True)):
    ecfg = EngineConfig(train_batch_size=batch, total_steps=100,
                        warmup_steps=1, guard_anomalies=guard)
    eng = DistributedEngine(cfg, ecfg, mesh)
    state = eng.init_state(seed=0)
    step = eng.jit_train_step(donate=False)
    b = concrete_batch(cfg, batch, 32, seed=0)
    with mesh:
        step(state, b)[1]["loss"].block_until_ready()   # warmup
        t0 = time.time()
        for _ in range(steps):
            s, m = step(state, b)
            if guard:
                # the production loop's host-side skip check is part of
                # the guarded step cost: one scalar readback per step
                assert bool(np.asarray(m["step_ok"]))
        jax.block_until_ready(m["loss"])
        out[name] = (time.time() - t0) / steps * 1e6
print("GUARD_JSON " + json.dumps(out))
"""


def bench_guard_overhead(rows):
    """guard_off vs guard_on step time (in-jit finite checks + select +
    per-step step_ok readback) — the resilience tentpole's <= 2% bar."""
    from benchmarks.common import child_env
    r = subprocess.run(
        [sys.executable, "-c", _GUARD_CHILD, "64", "16"],
        capture_output=True, text=True, timeout=1200,
        env=child_env(DEVICES))
    if r.returncode != 0:
        raise RuntimeError(f"guard bench failed:\n{r.stderr[-2000:]}")
    line = next(ln for ln in r.stdout.splitlines()
                if ln.startswith("GUARD_JSON "))
    res = json.loads(line[len("GUARD_JSON "):])
    rows.append(f"guard_off,{res['off']:.2f},unguarded train step")
    rows.append(
        f"guard_on,{res['on']:.2f},"
        f"rel_step={res['on'] / res['off']:.3f};in-jit finite checks + "
        f"no-op select + host step_ok readback (core/engine.py)")


ALL = [bench_scaling_layouts, bench_pp_peak_mem, bench_data_prefetch,
       bench_guard_overhead]
