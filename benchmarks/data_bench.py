"""Data & evaluation suite: the real-image workload as a tracked artifact
(``BENCH_data.json``) — samples/sec of the procedural-CIFAR ViT smoke
workload per dp x pp layout, augmentation on/off, the host-prefetch x
augmentation interaction, sharded-eval throughput, the uint8-vs-fp32
host-path comparison (``uint8_on/off``), and prefetch pipeline depth
(``prefetch_depth``).

Same shape as the scaling suite: each measurement runs in a subprocess
(host device count is fixed at jax init) and prints one JSON line the
parent turns into ``name,us_per_call,derived`` rows. CPU-host numbers are
substrate-relative; the layout/aug/prefetch *ratios* are the signal the
paper reports (per-layout samples/sec + accuracy as the joint scaling
metric).
"""
from __future__ import annotations

import json
import subprocess
import sys

DEVICES = 8
BATCH = 32
ACCUM = 4
STEPS = 3
# dp x pp layouts; augmentation only composes with pp=1 (the 1F1B path has
# no per-microbatch rng stream), so pp>1 rows are aug-off by construction
TRAIN_CASES = (
    (8, 1, 0), (8, 1, 1),
    (4, 2, 0), (2, 4, 0),
)

_TRAIN_CHILD = r"""
import json, sys, time
import jax
from repro.configs import get_smoke_config, EngineConfig
from repro.core import sharding as shd
from repro.core.engine import DistributedEngine
from repro.data import AugmentConfig, CIFARSource, DataPipeline
from repro.launch.mesh import make_local_mesh

dp, pp, aug_on, batch, accum, steps = (int(a) for a in sys.argv[1:7])
cfg = get_smoke_config("vit-b16").replace(dtype="float32", num_layers=4)
mesh = make_local_mesh(model=1, pipe=pp)
ecfg = EngineConfig(train_batch_size=batch, gradient_accumulation_steps=accum,
                    total_steps=100, warmup_steps=1, pipeline_stages=pp)
aug = AugmentConfig(num_classes=cfg.num_classes) if aug_on else None
source = CIFARSource("cifar10", seed=0)
eng = DistributedEngine(cfg, ecfg, mesh, aug=aug, preproc=source.preproc)
pipe = DataPipeline(kind="image", global_batch=batch, source=source)
state = eng.init_state(seed=0)
step = eng.jit_train_step(donate=False)
bshard = shd.named(mesh, shd.batch_specs(cfg, pipe.batch_shapes(), mesh))
with mesh:
    b = pipe.device_put(pipe.batch_at(0, 0), bshard)
    step(state, b)[1]["loss"].block_until_ready()   # compile warmup
    t0 = time.time()
    e, i = 0, 1
    for _ in range(steps):
        b = pipe.device_put(pipe.batch_at(e, i), bshard)
        out = step(state, b)
        e, i = pipe.next_cursor(e, i)
    jax.block_until_ready(out)
    dt = (time.time() - t0) / steps
print("DATA_JSON " + json.dumps({
    "dp": dp, "pp": pp, "aug": bool(aug_on), "step_us": dt * 1e6,
    "samples_per_sec": batch / dt, "loss": float(out[1]["loss"])}))
"""

_EVAL_CHILD = r"""
import json, sys, time
import jax
from repro.configs import get_smoke_config, EngineConfig
from repro.core.engine import DistributedEngine
from repro.data import CIFARSource
from repro.launch.mesh import make_local_mesh

batch, eval_size = int(sys.argv[1]), int(sys.argv[2])
cfg = get_smoke_config("vit-b16").replace(dtype="float32", num_layers=4)
mesh = make_local_mesh()
ecfg = EngineConfig(train_batch_size=batch, total_steps=100, warmup_steps=1)
source = CIFARSource("cifar10", seed=0, eval_size=eval_size)
eng = DistributedEngine(cfg, ecfg, mesh, preproc=source.preproc)
state = eng.init_state(seed=0)
eval_fn = eng.jit_eval_step()
eng.evaluate(state, source.eval_batches(batch), eval_step=eval_fn)  # warmup
t0 = time.time()
res = eng.evaluate(state, source.eval_batches(batch), eval_step=eval_fn)
dt = time.time() - t0
print("DATA_JSON " + json.dumps({
    "eval_samples_per_sec": res["eval_count"] / dt,
    "eval_us": dt * 1e6, "count": res["eval_count"],
    "batches": source.num_eval_batches(batch),
    "top1_count": res["eval_top1_count"]}))
"""

# host-prefetch x on-device augmentation interaction: augmentation adds
# device work per step, which gives the one-deep background prefetcher
# MORE room to hide host synthesis + device_put — the rel_step ratios
# quantify that coupling
_PREFETCH_CHILD = r"""
import json, sys, time
import jax
from repro.configs import get_smoke_config, EngineConfig
from repro.core import sharding as shd
from repro.core.engine import DistributedEngine
from repro.data import AugmentConfig, CIFARSource, DataPipeline
from repro.launch.mesh import make_local_mesh

batch, steps = int(sys.argv[1]), int(sys.argv[2])
cfg = get_smoke_config("vit-b16").replace(dtype="float32")
mesh = make_local_mesh()
out = {}
for aug_name, aug_on in (("augoff", 0), ("augon", 1)):
    ecfg = EngineConfig(train_batch_size=batch, total_steps=100,
                        warmup_steps=1)
    aug = AugmentConfig(num_classes=cfg.num_classes) if aug_on else None
    source = CIFARSource("cifar10", seed=0)
    eng = DistributedEngine(cfg, ecfg, mesh, aug=aug,
                            preproc=source.preproc)
    pipe = DataPipeline(kind="image", global_batch=batch, source=source)
    state = eng.init_state(seed=0)
    step = eng.jit_train_step(donate=False)
    bshard = shd.named(mesh, shd.batch_specs(cfg, pipe.batch_shapes(), mesh))

    def run_sync():
        s, e, i = state, 0, 0
        for _ in range(steps):
            b = pipe.device_put(pipe.batch_at(e, i), bshard)
            s, m = step(s, b)
            e, i = pipe.next_cursor(e, i)
        return m

    def run_prefetch():
        s = state
        # depth pinned to 1: this row is the legacy one-deep baseline the
        # prefetch_depth rows are measured against
        with pipe.prefetch(0, 0, shardings=bshard, depth=1) as pf:
            for _ in range(steps):
                _, b, _ = next(pf)
                s, m = step(s, b)
        return m

    with mesh:
        for pf_name, fn in (("prefoff", run_sync), ("prefon", run_prefetch)):
            fn()  # warmup (compile + thread spin-up)
            t0 = time.time()
            jax.block_until_ready(fn()["loss"])
            out[f"{pf_name}_{aug_name}"] = (time.time() - t0) / steps * 1e6
print("DATA_JSON " + json.dumps(out))
"""


# uint8 host path vs the old fp32 host path, END TO END ON THE HOST SIDE:
# both paths start from the same uint8 source batch and end with a
# normalized fp32 model-resolution tensor on device. "off" is the legacy
# path (host normalize -> host upsample -> fp32 device_put); "on" is the
# timm-PrefetchLoader path (uint8 device_put -> jitted on-device
# upsample+normalize). 4x fewer transferred bytes and no host fp32
# materialization — the samples/sec ratio is the tentpole's win.
_UINT8_CHILD = r"""
import json, sys, time
import jax
from repro.data.augment import device_preprocess
from repro.data.datasets import CIFARSource, _upsample, normalize_images

batch, res, steps = (int(a) for a in sys.argv[1:4])
source = CIFARSource("cifar10", seed=0, resolution=res)
pre = source.preproc

@jax.jit
def finish(b):
    return device_preprocess(b, pre, res)["images"]

def path_uint8(seed):
    b = source.train_batch(batch, seed=seed)
    return finish({k: jax.device_put(v) for k, v in b.items()})

def path_fp32(seed):
    b = source.train_batch(batch, seed=seed)
    img = _upsample(normalize_images(b["images"], pre.mean, pre.std), res)
    return jax.device_put(img)

out = {}
for name, fn in (("uint8_off", path_fp32), ("uint8_on", path_uint8)):
    jax.block_until_ready(fn(0))    # warmup (compile; allocator touch)
    t0 = time.time()
    for s in range(1, steps + 1):
        x = fn(s)
    jax.block_until_ready(x)
    dt = (time.time() - t0) / steps
    out[name] = {"us": dt * 1e6, "samples_per_sec": batch / dt}
print("DATA_JSON " + json.dumps(out))
"""

# prefetch pipeline depth on the real train step: depth=1 is the old
# one-deep behavior (queue of 1 per stage), deeper pipelines overlap
# synthesis, device_put, and the running step
_DEPTH_CHILD = r"""
import json, sys, time
import jax
from repro.configs import get_smoke_config, EngineConfig
from repro.core import sharding as shd
from repro.core.engine import DistributedEngine
from repro.data import CIFARSource, DataPipeline
from repro.launch.mesh import make_local_mesh

batch, steps = int(sys.argv[1]), int(sys.argv[2])
cfg = get_smoke_config("vit-b16").replace(dtype="float32")
mesh = make_local_mesh()
ecfg = EngineConfig(train_batch_size=batch, total_steps=100, warmup_steps=1)
source = CIFARSource("cifar10", seed=0)
eng = DistributedEngine(cfg, ecfg, mesh, preproc=source.preproc)
pipe = DataPipeline(kind="image", global_batch=batch, source=source)
state = eng.init_state(seed=0)
step = eng.jit_train_step(donate=False)
bshard = shd.named(mesh, shd.batch_specs(cfg, pipe.batch_shapes(), mesh))

def run(depth):
    s = state
    with pipe.prefetch(0, 0, shardings=bshard, depth=depth) as pf:
        for _ in range(steps):
            _, b, _ = next(pf)
            s, m = step(s, b)
    return m

out = {}
with mesh:
    for depth in (1, 2, 4):
        run(depth)  # warmup (compile + thread spin-up)
        t0 = time.time()
        jax.block_until_ready(run(depth)["loss"])
        out[str(depth)] = (time.time() - t0) / steps * 1e6
print("DATA_JSON " + json.dumps(out))
"""


def _run_child(code: str, *argv, devices: int = DEVICES) -> dict:
    from benchmarks.common import child_env
    r = subprocess.run(
        [sys.executable, "-c", code] + [str(a) for a in argv],
        capture_output=True, text=True, timeout=1200,
        env=child_env(devices))
    if r.returncode != 0:
        raise RuntimeError(f"data bench child failed:\n{r.stderr[-2000:]}")
    for line in r.stdout.splitlines():
        if line.startswith("DATA_JSON "):
            return json.loads(line[len("DATA_JSON "):])
    raise RuntimeError(f"no DATA_JSON line in child output:\n{r.stdout}")


def bench_data_layouts(rows):
    """samples/sec per dp x pp layout, augmentation on/off, on the
    procedural-CIFAR ViT smoke workload (the paper's joint
    throughput-per-layout signal)."""
    results = [_run_child(_TRAIN_CHILD, dp, pp, aug, BATCH, ACCUM, STEPS)
               for dp, pp, aug in TRAIN_CASES]
    base = results[0]["samples_per_sec"]
    for res in results:
        aug = "on" if res["aug"] else "off"
        rows.append(
            f"data_dp{res['dp']}_pp{res['pp']}_aug{aug},"
            f"{res['step_us']:.2f},"
            f"samples_per_sec={res['samples_per_sec']:.2f};"
            f"rel_tput={res['samples_per_sec'] / base:.3f};"
            f"loss={res['loss']:.4f}")


def bench_eval_loop(rows):
    """Sharded-eval throughput over the padded procedural test split
    (dp8, non-divisible final batch exercises the mask path)."""
    res = _run_child(_EVAL_CHILD, 64, 500)
    rows.append(
        f"data_eval_dp8,{res['eval_us']:.2f},"
        f"eval_samples_per_sec={res['eval_samples_per_sec']:.2f};"
        f"count={res['count']};batches={res['batches']};"
        f"top1_count={res['top1_count']}")


def bench_prefetch_aug(rows):
    """Prefetch on/off x augmentation on/off step times (single process,
    dp8): how much of the host data path the background prefetcher hides
    once augmentation moves compute on-device."""
    res = _run_child(_PREFETCH_CHILD, 256, 6)
    for aug in ("augoff", "augon"):
        off, on = res[f"prefoff_{aug}"], res[f"prefon_{aug}"]
        rows.append(f"data_prefoff_{aug},{off:.2f},sync host path")
        rows.append(f"data_prefon_{aug},{on:.2f},"
                    f"rel_step={on / off:.3f};one-deep background prefetch")


def bench_uint8_path(rows):
    """uint8-to-device vs fp32-on-host data path, batch 256 at 128px (a
    4x CIFAR upsample — the resolution gap any ViT-on-CIFAR run has):
    host-path samples/sec, where the acceptance bar is uint8_on >= 1.2x
    uint8_off."""
    res = _run_child(_UINT8_CHILD, 256, 128, 8, devices=1)
    base = res["uint8_off"]["samples_per_sec"]
    for name in ("uint8_off", "uint8_on"):
        r = res[name]
        rel = r["samples_per_sec"] / base
        what = "fp32 host normalize+upsample then device_put" \
            if name == "uint8_off" else \
            "uint8 device_put then jitted on-device upsample+normalize"
        rows.append(f"data_{name},{r['us']:.2f},"
                    f"samples_per_sec={r['samples_per_sec']:.2f};"
                    f"rel_tput={rel:.3f};{what}")


def bench_prefetch_depth(rows):
    """Two-stage prefetch pipeline depth (1/2/4) on the real dp8 train
    step: depth 1 reproduces the old one-deep behavior; deeper pipelines
    overlap synthesis, transfer, and compute."""
    res = _run_child(_DEPTH_CHILD, 256, 6)
    base = res["1"]
    for depth in (1, 2, 4):
        us = res[str(depth)]
        rows.append(f"data_prefetch_depth{depth},{us:.2f},"
                    f"rel_step={us / base:.3f};two-stage pipeline, "
                    f"depth {depth} per stage")


ALL = [bench_data_layouts, bench_eval_loop, bench_prefetch_aug,
       bench_uint8_path, bench_prefetch_depth]
