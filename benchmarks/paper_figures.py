"""One benchmark per paper table/figure (§IV). Each returns CSV rows
``name,us_per_call,derived`` where `derived` carries the figure's headline
quantity (scaling efficiency, sync fraction, speedup, accuracy...).

Measured: reduced-ViT step time on this host. Modeled: cluster collectives
(core.comm_model) with the paper's cluster parameters (Fig. 3).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    ETHERNET_10G,
    IB_25G,
    NVLINK_NODE,
    emit,
    scale_to_gpu,
    vit_step_time_and_bytes,
)
from repro.core.comm_model import (
    GPU_SPECS,
    StepModel,
    strong_scaling_times,
    weak_scaling_times,
)

T4 = GPU_SPECS["t4"]


def fig4_5_tesla_scaling(rows):
    """Figs. 4-5: inter-node strong/weak scaling on the heterogeneous Tesla
    cluster (3x RTX3070 + GTX1070 + Tesla P4) — reproduces the paper's
    anti-scaling at 4-5 GPUs."""
    cpu_t, grad_bytes = vit_step_time_and_bytes()
    t_ref = scale_to_gpu(cpu_t, 16, GPU_SPECS["rtx3070"])
    hetero = [1.0, 1.0, 1.0,
              GPU_SPECS["gtx1070"] / GPU_SPECS["rtx3070"],
              GPU_SPECS["tesla_p4"] / GPU_SPECS["rtx3070"]]
    counts = [1, 2, 3, 4, 5]
    strong = strong_scaling_times(t_ref, grad_bytes, counts,
                                  comm_bw=ETHERNET_10G, hetero=hetero)
    weak = weak_scaling_times(t_ref, grad_bytes, counts,
                              comm_bw=ETHERNET_10G, hetero=hetero)
    anti = strong[4] > strong[2]      # paper: adding weak GPUs HURTS
    emit(rows, "fig4_tesla_strong_5gpu", strong[4] * 1e6,
         f"anti_scaling={anti};t1={strong[0]:.3f}s;t5={strong[4]:.3f}s")
    emit(rows, "fig5_tesla_weak_5gpu", weak[4] * 1e6,
         f"flat={max(weak)/min(weak):.2f}x")


def fig6_sync_overhead(rows):
    """Fig. 6: synchronization cost share vs per-GPU batch size (Nebula,
    2 GPUs). Sync fraction must fall with batch and plateau at 128-256."""
    cpu_t16, grad_bytes = vit_step_time_and_bytes(16)
    fracs = {}
    for bs in (16, 32, 64, 128, 256):
        t = scale_to_gpu(cpu_t16 * bs / 16, bs, GPU_SPECS["rtx2080ti"])
        m = StepModel(grad_bytes=grad_bytes, compute_times=[t, t],
                      comm_bw=NVLINK_NODE,
                      infeed_bytes_per_mb=bs * 224 * 224 * 3 * 4)
        fracs[bs] = m.sync_fraction()
        emit(rows, f"fig6_sync_frac_b{bs}", m.step_time() * 1e6,
             f"sync_frac={fracs[bs]:.3f}")
    assert fracs[16] > fracs[128], fracs
    plateau = abs(fracs[256] - fracs[128]) < abs(fracs[32] - fracs[16])
    emit(rows, "fig6_plateau_128_256", 0.0, f"plateau={plateau}")


def fig7_accuracy_vs_batch(rows):
    """Fig. 7: train accuracy vs batch size — real reduced-ViT trainings on
    synthetic CIFAR-10 (trend: moderate batch optimal at fixed steps)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import EngineConfig, get_smoke_config
    from repro.core.engine import DistributedEngine
    from repro.data import DATASETS, DataPipeline
    from repro.launch.mesh import make_local_mesh

    accs = {}
    for bs in (8, 32, 128):
        cfg = get_smoke_config("vit-b16").replace(dtype="float32")
        mesh = make_local_mesh()
        eng = DistributedEngine(cfg, EngineConfig(
            train_batch_size=bs, lr=1e-3, total_steps=25, warmup_steps=2),
            mesh)
        pipe = DataPipeline(kind="image", global_batch=bs,
                            dataset=DATASETS["cifar10"],
                            resolution=cfg.image_size)
        state = eng.init_state(seed=0)
        step = eng.jit_train_step(donate=False)
        acc = 0.0
        with mesh:
            for i, b in enumerate(pipe.batches()):
                if i >= 25:
                    break
                b = jax.tree.map(jnp.asarray, b)
                state, m = step(state, b)
                acc = float(m["acc"])
        accs[bs] = acc
        emit(rows, f"fig7_acc_b{bs}", 0.0, f"train_acc={acc:.3f}")


def fig8_9_vector_scaling(rows):
    """Figs. 8-9 (+16-17): homogeneous T4 strong/weak scaling on Vector."""
    cpu_t, grad_bytes = vit_step_time_and_bytes()
    t_ref = scale_to_gpu(cpu_t * 4, 64, T4)           # batch 64
    counts = [1, 2, 4, 8]
    strong = strong_scaling_times(t_ref, grad_bytes, counts,
                                  comm_bw=NVLINK_NODE)
    weak = weak_scaling_times(t_ref, grad_bytes, counts,
                              comm_bw=NVLINK_NODE)
    half = strong[1] / strong[0]
    emit(rows, "fig8_vector_strong_2gpu", strong[1] * 1e6,
         f"t2/t1={half:.3f} (paper: ~0.5)")
    emit(rows, "fig9_vector_weak_8gpu", weak[3] * 1e6,
         f"flat={max(weak)/min(weak):.2f}x")
    assert 0.4 < half < 0.75, half


def fig12_13_speedup(rows):
    """Figs. 12-13: strong-scaling speedup at batch 16 vs 64 — larger batch
    gives the better speedup curve."""
    cpu_t, grad_bytes = vit_step_time_and_bytes()
    counts = [1, 2, 4, 8]
    out = {}
    for bs in (16, 64):
        t_ref = scale_to_gpu(cpu_t * bs / 16, bs, T4)
        times = strong_scaling_times(t_ref, grad_bytes, counts,
                                     comm_bw=NVLINK_NODE)
        speedup = times[0] / np.array(times)
        out[bs] = speedup[-1]
        emit(rows, f"fig12_speedup8_b{bs}", times[-1] * 1e6,
             f"speedup_8gpu={speedup[-1]:.2f}")
    assert out[64] > out[16], out
    emit(rows, "fig13_larger_batch_scales_better", 0.0,
         f"b64={out[64]:.2f}x > b16={out[16]:.2f}x")


def fig14_15_multinode(rows):
    """Figs. 14-15: multi-node single-GPU (inter-node IB) vs single-node
    multi-GPU (NVLink) strong scaling to 32 — paper: no significant gap."""
    cpu_t, grad_bytes = vit_step_time_and_bytes()
    t_ref = scale_to_gpu(cpu_t * 4, 64, T4)
    counts = [1, 2, 4, 8, 16, 32]
    inter = strong_scaling_times(t_ref, grad_bytes, counts, comm_bw=IB_25G)
    intra = strong_scaling_times(t_ref, grad_bytes, counts,
                                 comm_bw=NVLINK_NODE)
    gap = inter[-1] / intra[-1]
    emit(rows, "fig14_multinode_strong_32", inter[-1] * 1e6,
         f"t32={inter[-1]*1e3:.2f}ms speedup={inter[0]/inter[-1]:.1f}x")
    emit(rows, "fig15_inter_vs_intra_gap", 0.0,
         f"gap={gap:.2f}x (paper: ~1)")


ALL = [fig4_5_tesla_scaling, fig6_sync_overhead, fig7_accuracy_vs_batch,
       fig8_9_vector_scaling, fig12_13_speedup, fig14_15_multinode]
