"""§Roofline report: reads the dry-run JSONL produced by
``python -m repro.launch.dryrun --all --out results/...jsonl`` and emits one
CSV row per (arch x shape) with the three roofline terms + the bottleneck.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load(path):
    recs = {}
    if not os.path.exists(path):
        return recs
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"])] = r   # last write wins
    return recs


def roofline_rows(rows, fname="baseline_singlepod.jsonl",
                  prefix="roofline"):
    recs = load(os.path.join(RESULTS, fname))
    n_ok = n_skip = 0
    for (arch, shape), r in sorted(recs.items()):
        if r["status"] == "skip":
            n_skip += 1
            continue
        if r["status"] != "ok":
            emit(rows, f"{prefix}_{arch}_{shape}", 0.0,
                 f"FAIL:{r.get('error', '?')[:60]}")
            continue
        n_ok += 1
        rl = r["roofline"]
        emit(rows, f"{prefix}_{arch}_{shape}",
             rl["bound_step_s"] * 1e6,
             f"dom={rl['dominant'][:-2]};compute={rl['compute_s']*1e3:.1f}ms"
             f";mem={rl['memory_s']*1e3:.1f}ms"
             f";coll={rl['collective_s']*1e3:.1f}ms"
             f";useful={min(rl['useful_flops_frac'], 9.99):.2f}"
             f";mem_dev={r['peak_bytes_per_dev']/2**30:.1f}GiB")
    emit(rows, f"{prefix}_summary", 0.0, f"ok={n_ok};skip={n_skip}")


def run(rows):
    roofline_rows(rows)
    roofline_rows(rows, "optimized_singlepod.jsonl", prefix="roofline_opt")


ALL = [run]
