"""Benchmark harness: one function per paper table/figure + kernel micro-
benchmarks + the dry-run roofline report. Prints ``name,us_per_call,derived``
CSV (the repo contract) and writes the kernel rows to ``BENCH_kernels.json``
(the canonical perf-trajectory artifact CI uploads — PR-over-PR kernel
timings and oracle errors live there).

``--suite kernels`` runs only the kernel + attention-backward suites (the
CI fast path); ``--suite scaling`` runs the dp x pp layout sweep on 8 host
devices (subprocess per layout) and writes ``BENCH_scaling.json`` — the
second trajectory artifact: per-layout step time, 1F1B bubble fraction,
and collective bytes. ``--suite data`` runs the real-image workload suite
(procedural-CIFAR samples/sec per layout, aug on/off, prefetch x aug,
sharded-eval throughput) and writes ``BENCH_data.json`` — the third
trajectory artifact. Default runs the paper + kernel + roofline suites
(scaling/data stay opt-in: they re-exec with a different device count).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback


def _row_dict(row: str) -> dict:
    name, us, derived = row.split(",", 2)
    return {"name": name, "us_per_call": float(us), "derived": derived}


def _write_rows_json(rows_subset, path: str, schema: str, substrate: str,
                     note: str) -> None:
    payload = {
        "schema": schema,
        "substrate": substrate,
        "note": note,
        "rows": [_row_dict(r) for r in rows_subset],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--suite",
                        choices=("all", "kernels", "scaling", "data"),
                        default="all")
    parser.add_argument("--json-out", default="BENCH_kernels.json",
                        help="kernel-row JSON artifact path")
    parser.add_argument("--scaling-json-out", default="BENCH_scaling.json",
                        help="scaling-row JSON artifact path")
    parser.add_argument("--data-json-out", default="BENCH_data.json",
                        help="data/eval-row JSON artifact path")
    args = parser.parse_args(argv)

    from benchmarks import attn_bwd_bench, data_bench, kernel_bench, \
        paper_figures, roofline_report, scaling_bench

    kernel_suites = kernel_bench.ALL + attn_bwd_bench.ALL
    scaling_suites = scaling_bench.ALL
    data_suites = data_bench.ALL
    if args.suite == "kernels":
        suites = kernel_suites
    elif args.suite == "scaling":
        suites = scaling_suites
    elif args.suite == "data":
        suites = data_suites
    else:
        suites = (paper_figures.ALL + kernel_suites + roofline_report.ALL)
    kernel_set = set(kernel_suites)
    scaling_set = set(scaling_suites)
    data_set = set(data_suites)

    header = "name,us_per_call,derived"
    rows = [header]
    kernel_rows = []
    scaling_rows = []
    data_rows = []
    t0 = time.time()
    failures = 0
    for fn in suites:
        start = len(rows)
        try:
            fn(rows)
        except Exception:  # noqa: BLE001 — report all suites
            traceback.print_exc()
            rows.append(f"{fn.__name__},0.00,ERROR")
            failures += 1
        if fn in kernel_set:
            kernel_rows.extend(rows[start:])
        if fn in scaling_set:
            scaling_rows.extend(rows[start:])
        if fn in data_set:
            data_rows.extend(rows[start:])
    artifacts = []
    if args.suite not in ("scaling", "data"):
        _write_rows_json(
            kernel_rows, args.json_out, "repro/kernel-bench/v1",
            "pallas-interpret-cpu",
            "CPU-interpret relative timings; derived carries oracle "
            "max-error and grid-cell/DMA-pruning counts (the deploy gates)")
        artifacts.append(os.path.abspath(args.json_out))
    if scaling_rows:
        _write_rows_json(
            scaling_rows, args.scaling_json_out, "repro/scaling-bench/v1",
            "cpu-host-devices",
            "dp x pp layout sweep (8 host devices, vit-b16 smoke): step "
            "time is substrate-relative; bubble_frac (analytic 1F1B) and "
            "collective bytes (trip-count-aware HLO) are the layout-"
            "comparison signal")
        artifacts.append(os.path.abspath(args.scaling_json_out))
    if data_rows:
        _write_rows_json(
            data_rows, args.data_json_out, "repro/data-bench/v1",
            "cpu-host-devices",
            "real-image workload (procedural CIFAR, vit-b16 smoke): "
            "samples/sec per dp x pp layout and aug on/off, prefetch x "
            "aug interaction, sharded-eval throughput; CPU-relative — "
            "the layout/aug/prefetch ratios are the signal")
        artifacts.append(os.path.abspath(args.data_json_out))
    print("\n".join(rows))
    print(f"# {len(rows)-1} rows in {time.time()-t0:.1f}s, "
          f"{failures} failures; artifacts: {', '.join(artifacts)}",
          file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
