"""Benchmark harness: one function per paper table/figure + kernel micro-
benchmarks + the dry-run roofline report. Prints ``name,us_per_call,derived``
CSV (the repo contract)."""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import attn_bwd_bench, kernel_bench, paper_figures, \
        roofline_report

    rows = ["name,us_per_call,derived"]
    suites = (paper_figures.ALL + kernel_bench.ALL + attn_bwd_bench.ALL
              + roofline_report.ALL)
    t0 = time.time()
    failures = 0
    for fn in suites:
        try:
            fn(rows)
        except Exception:  # noqa: BLE001 — report all suites
            traceback.print_exc()
            rows.append(f"{fn.__name__},0.00,ERROR")
            failures += 1
    print("\n".join(rows))
    print(f"# {len(rows)-1} rows in {time.time()-t0:.1f}s, "
          f"{failures} failures", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
