"""Benchmark harness: one function per paper table/figure + kernel micro-
benchmarks + the dry-run roofline report. Prints ``name,us_per_call,derived``
CSV (the repo contract) and writes the kernel rows to ``BENCH_kernels.json``
(the canonical perf-trajectory artifact CI uploads — PR-over-PR kernel
timings and oracle errors live there).

``--suite kernels`` runs only the kernel + attention-backward suites (the
CI fast path); default runs everything.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback


def _row_dict(row: str) -> dict:
    name, us, derived = row.split(",", 2)
    return {"name": name, "us_per_call": float(us), "derived": derived}


def _write_kernel_json(kernel_rows, path: str) -> None:
    payload = {
        "schema": "repro/kernel-bench/v1",
        "substrate": "pallas-interpret-cpu",
        "note": ("CPU-interpret relative timings; derived carries oracle "
                 "max-error and grid-cell/DMA-pruning counts (the deploy "
                 "gates)"),
        "rows": [_row_dict(r) for r in kernel_rows],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--suite", choices=("all", "kernels"), default="all")
    parser.add_argument("--json-out", default="BENCH_kernels.json",
                        help="kernel-row JSON artifact path")
    args = parser.parse_args(argv)

    from benchmarks import attn_bwd_bench, kernel_bench, paper_figures, \
        roofline_report

    kernel_suites = kernel_bench.ALL + attn_bwd_bench.ALL
    if args.suite == "kernels":
        suites = kernel_suites
    else:
        suites = (paper_figures.ALL + kernel_suites + roofline_report.ALL)
    kernel_set = set(kernel_suites)

    header = "name,us_per_call,derived"
    rows = [header]
    kernel_rows = []
    t0 = time.time()
    failures = 0
    for fn in suites:
        start = len(rows)
        try:
            fn(rows)
        except Exception:  # noqa: BLE001 — report all suites
            traceback.print_exc()
            rows.append(f"{fn.__name__},0.00,ERROR")
            failures += 1
        if fn in kernel_set:
            kernel_rows.extend(rows[start:])
    _write_kernel_json(kernel_rows, args.json_out)
    print("\n".join(rows))
    print(f"# {len(rows)-1} rows in {time.time()-t0:.1f}s, "
          f"{failures} failures; kernel rows -> "
          f"{os.path.abspath(args.json_out)}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
