"""Kernel micro-benchmarks: Pallas (interpret) correctness-path timing vs
pure-jnp reference, plus the blockwise-attention XLA path that the dry-run
memory numbers rest on. On CPU these are *relative* numbers; the derived
column carries the oracle max-error (the deploy gate).

Every train-path kernel gets a fwd row AND a fwd+bwd row (the backward is
the training hot path), and the flash block-skip ablation records the
*launched grid-cell* counts — under index-map-level pruning the skipped
K-blocks are never DMA'd, so ``grid_cells`` IS the HBM-traffic/FLOP saving
by construction (not just a predicate-skip count)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.ref import ref_attention, ref_rmsnorm, ref_wkv6
from repro.models.blockwise import blockwise_attention_qchunked


def bench_attention(rows):
    key = jax.random.PRNGKey(0)
    b, h, kh, s, d = 1, 8, 2, 1024, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kh, d))
    v = jax.random.normal(ks[2], (b, s, kh, d))

    qT = q.transpose(0, 2, 1, 3)
    kT = k.transpose(0, 2, 1, 3)
    vT = v.transpose(0, 2, 1, 3)
    f_ref = jax.jit(lambda a, b_, c: ref_attention(a, b_, c, causal=True))
    t_ref = time_fn(f_ref, qT, kT, vT)
    f_blk = jax.jit(lambda a, b_, c: blockwise_attention_qchunked(
        a, b_, c, 0, causal=True, block_k=256, block_q=256))
    t_blk = time_fn(f_blk, q, k, v)
    err = float(jnp.max(jnp.abs(
        f_blk(q, k, v) - f_ref(qT, kT, vT).transpose(0, 2, 1, 3))))
    emit(rows, "attn_naive_s1024", t_ref * 1e6, "oracle")
    emit(rows, "attn_blockwise_s1024", t_blk * 1e6,
         f"max_err={err:.1e};ratio={t_blk/t_ref:.2f}")


def bench_flash_grid_pruning(rows):
    """DMA-pruning ablation (grid pruning on/off): causal and windowed at
    s=1024. ``grid_cells`` is the launched grid (skipped K-blocks are not
    DMA'd under index-map pruning); causal ≈ ½ of dense."""
    from repro.kernels.flash_attention import flash_attention, grid_cells
    key = jax.random.PRNGKey(4)
    b, h, s, d, blk = 1, 4, 1024, 64, 128
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, h, s, d))
    v = jax.random.normal(ks[2], (b, h, s, d))
    for name, causal, window in [("causal", True, 0),
                                 ("window128", True, 128)]:
        fns = {}
        for skip in (True, False):
            fns[skip] = jax.jit(lambda q, k, v, _s=skip: flash_attention(
                q, k, v, causal=causal, window=window, block_q=blk,
                block_k=blk, interpret=True, block_skip=_s))
        t_skip = time_fn(fns[True], q, k, v, iters=5, warmup=1)
        t_full = time_fn(fns[False], q, k, v, iters=5, warmup=1)
        err = float(jnp.max(jnp.abs(fns[True](q, k, v)
                                    - fns[False](q, k, v))))
        live, dense = grid_cells(s, s, causal=causal, window=window,
                                 block_q=blk, block_k=blk)
        # dma_ratio is the real (TPU) HBM-traffic AND FLOP saving: only
        # `live` cells are launched, so only their K/V tiles are copied.
        # interp_time_ratio is CPU-interpret-mode only.
        emit(rows, f"flash_grid_{name}_s1024", t_skip * 1e6,
             f"grid_cells={live}/{dense};dma_ratio={live/dense:.3f};"
             f"interp_time_ratio={t_skip/t_full:.2f};max_err={err:.1e}")
        emit(rows, f"flash_dense_{name}_s1024", t_full * 1e6,
             "ablation_baseline")


def _grad_max_err(ga, gb):
    return max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32))))
               for a, b in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)))


def bench_wkv6(rows):
    """wkv6 fwd and fwd+bwd vs the sequential oracle — the bwd runs the
    reverse-chunk Pallas kernel through the custom VJP."""
    from repro.kernels.ops import wkv6
    key = jax.random.PRNGKey(1)
    b, s, h, p = 1, 512, 4, 64
    ks = jax.random.split(key, 6)
    r, k, v = (jax.random.normal(ks[i], (b, s, h, p)) for i in range(3))
    wlog = -jnp.exp(jax.random.normal(ks[3], (b, s, h, p)) - 0.5)
    u = 0.3 * jax.random.normal(ks[4], (h, p))
    s0 = jnp.zeros((b, h, p, p))
    args = (r, k, v, wlog, u, s0)

    f_ref = jax.jit(lambda *a: ref_wkv6(*a)[0])
    f_kern = jax.jit(lambda *a: wkv6(*a, chunk=32, interpret=True)[0])
    t_ref = time_fn(f_ref, *args)
    t_kern = time_fn(f_kern, *args)
    err = float(jnp.max(jnp.abs(f_kern(*args) - f_ref(*args))))
    emit(rows, "wkv6_fwd_ref_seq_s512", t_ref * 1e6, "oracle(sequential)")
    emit(rows, "wkv6_fwd_pallas_s512", t_kern * 1e6, f"max_err={err:.1e}")

    def gfn(fn):
        return jax.jit(jax.grad(
            lambda *a: jnp.sum(fn(*a)[0]), argnums=tuple(range(6))))
    g_ref = gfn(lambda *a: ref_wkv6(*a))
    g_kern = gfn(lambda *a: wkv6(*a, chunk=32, interpret=True))
    t_gref = time_fn(g_ref, *args, iters=3, warmup=1)
    t_gkern = time_fn(g_kern, *args, iters=3, warmup=1)
    gerr = _grad_max_err(g_kern(*args), g_ref(*args))
    emit(rows, "wkv6_fwdbwd_ref_seq_s512", t_gref * 1e6, "oracle(autodiff)")
    emit(rows, "wkv6_fwdbwd_pallas_s512", t_gkern * 1e6,
         f"max_grad_err={gerr:.1e};oracle=ref_wkv6")


def bench_rmsnorm(rows):
    """fused rmsnorm fwd and fwd+bwd — the bwd is the row-tiled dx/dscale
    kernel reusing the saved per-row inv-rms."""
    from repro.kernels.ops import fused_rmsnorm
    x = jax.random.normal(jax.random.PRNGKey(2), (4096, 1024))
    sc = jnp.ones((1024,))
    f_ref = jax.jit(lambda a, b: ref_rmsnorm(a, b))
    f_kern = jax.jit(lambda a, b: fused_rmsnorm(a, b, interpret=True))
    t_ref = time_fn(f_ref, x, sc)
    t_kern = time_fn(f_kern, x, sc)
    err = float(jnp.max(jnp.abs(f_kern(x, sc) - f_ref(x, sc))))
    emit(rows, "rmsnorm_fwd_ref_4096x1024", t_ref * 1e6, "oracle")
    emit(rows, "rmsnorm_fwd_pallas", t_kern * 1e6, f"max_err={err:.1e}")

    def gfn(fn):
        return jax.jit(jax.grad(
            lambda a, b: jnp.sum(fn(a, b)), argnums=(0, 1)))
    g_ref = gfn(ref_rmsnorm)
    g_kern = gfn(lambda a, b: fused_rmsnorm(a, b, interpret=True))
    t_gref = time_fn(g_ref, x, sc, iters=5, warmup=1)
    t_gkern = time_fn(g_kern, x, sc, iters=5, warmup=1)
    gerr = _grad_max_err(g_kern(x, sc), g_ref(x, sc))
    emit(rows, "rmsnorm_fwdbwd_ref_4096x1024", t_gref * 1e6,
         "oracle(autodiff)")
    emit(rows, "rmsnorm_fwdbwd_pallas", t_gkern * 1e6,
         f"max_grad_err={gerr:.1e};oracle=ref_rmsnorm")


ALL = [bench_attention, bench_flash_grid_pruning, bench_wkv6, bench_rmsnorm]
