"""Kernel micro-benchmarks: Pallas (interpret) correctness-path timing vs
pure-jnp reference, plus the blockwise-attention XLA path that the dry-run
memory numbers rest on. On CPU these are *relative* numbers; the derived
column carries the oracle max-error (the deploy gate)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels.ref import ref_attention, ref_rmsnorm, ref_wkv6
from repro.models.blockwise import blockwise_attention_qchunked


def bench_attention(rows):
    key = jax.random.PRNGKey(0)
    b, h, kh, s, d = 1, 8, 2, 1024, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kh, d))
    v = jax.random.normal(ks[2], (b, s, kh, d))

    qT = q.transpose(0, 2, 1, 3)
    kT = k.transpose(0, 2, 1, 3)
    vT = v.transpose(0, 2, 1, 3)
    f_ref = jax.jit(lambda a, b_, c: ref_attention(a, b_, c, causal=True))
    t_ref = time_fn(f_ref, qT, kT, vT)
    f_blk = jax.jit(lambda a, b_, c: blockwise_attention_qchunked(
        a, b_, c, 0, causal=True, block_k=256, block_q=256))
    t_blk = time_fn(f_blk, q, k, v)
    err = float(jnp.max(jnp.abs(
        f_blk(q, k, v) - f_ref(qT, kT, vT).transpose(0, 2, 1, 3))))
    emit(rows, "attn_naive_s1024", t_ref * 1e6, "oracle")
    emit(rows, "attn_blockwise_s1024", t_blk * 1e6,
         f"max_err={err:.1e};ratio={t_blk/t_ref:.2f}")


def _live_kblocks(s, t, bq, bk, *, causal, window):
    """Blocks the kernel executes under block-skip pruning — evaluates the
    kernel's own _block_dead predicate on host ints, so this IS the
    executed-tile/FLOP count by construction."""
    from repro.kernels.flash_attention import _block_dead
    nq, nk = -(-s // bq), -(-t // bk)
    live = sum(not _block_dead(int(causal), window, qi, ki, bq, bk)
               for qi in range(nq) for ki in range(nk))
    return live, nq * nk


def bench_flash_blockskip(rows):
    """Block-skip ablation (pruning on/off): causal and windowed at s=1024.
    FLOPs scale with executed K-blocks; time_ratio is interpret-mode."""
    from repro.kernels.flash_attention import flash_attention
    key = jax.random.PRNGKey(4)
    b, h, s, d, blk = 1, 4, 1024, 64, 128
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, h, s, d))
    v = jax.random.normal(ks[2], (b, h, s, d))
    for name, causal, window in [("causal", True, 0),
                                 ("window128", True, 128)]:
        fns = {}
        for skip in (True, False):
            fns[skip] = jax.jit(lambda q, k, v, _s=skip: flash_attention(
                q, k, v, causal=causal, window=window, block_q=blk,
                block_k=blk, interpret=True, block_skip=_s))
        t_skip = time_fn(fns[True], q, k, v, iters=5, warmup=1)
        t_full = time_fn(fns[False], q, k, v, iters=5, warmup=1)
        err = float(jnp.max(jnp.abs(fns[True](q, k, v)
                                    - fns[False](q, k, v))))
        live, total = _live_kblocks(s, s, blk, blk, causal=causal,
                                    window=window)
        # flop_ratio is the real (TPU) saving: the skip predicate is exact.
        # interp_time_ratio is CPU-interpret-mode only, where per-block
        # cond/DMA-emulation overhead swamps the skipped tile math.
        emit(rows, f"flash_skip_{name}_s1024", t_skip * 1e6,
             f"kblocks={live}/{total};flop_ratio={live/total:.3f};"
             f"interp_time_ratio={t_skip/t_full:.2f};max_err={err:.1e}")
        emit(rows, f"flash_noskip_{name}_s1024", t_full * 1e6,
             "ablation_baseline")


def bench_wkv6(rows):
    from repro.kernels.ops import wkv6
    key = jax.random.PRNGKey(1)
    b, s, h, p = 1, 512, 4, 64
    ks = jax.random.split(key, 6)
    r, k, v = (jax.random.normal(ks[i], (b, s, h, p)) for i in range(3))
    wlog = -jnp.exp(jax.random.normal(ks[3], (b, s, h, p)) - 0.5)
    u = 0.3 * jax.random.normal(ks[4], (h, p))
    s0 = jnp.zeros((b, h, p, p))
    f_ref = jax.jit(lambda *a: ref_wkv6(*a)[0])
    t_ref = time_fn(f_ref, r, k, v, wlog, u, s0)
    f_kern = jax.jit(lambda *a: wkv6(*a, chunk=32, interpret=True)[0])
    t_kern = time_fn(f_kern, r, k, v, wlog, u, s0)
    err = float(jnp.max(jnp.abs(f_kern(r, k, v, wlog, u, s0)
                                - f_ref(r, k, v, wlog, u, s0))))
    emit(rows, "wkv6_ref_seq_s512", t_ref * 1e6, "oracle(sequential)")
    emit(rows, "wkv6_pallas_interp_s512", t_kern * 1e6,
         f"max_err={err:.1e}")


def bench_rmsnorm(rows):
    from repro.kernels.ops import fused_rmsnorm
    x = jax.random.normal(jax.random.PRNGKey(2), (4096, 1024))
    sc = jnp.ones((1024,))
    f_ref = jax.jit(lambda a, b: ref_rmsnorm(a, b))
    f_kern = jax.jit(lambda a, b: fused_rmsnorm(a, b, interpret=True))
    t_ref = time_fn(f_ref, x, sc)
    t_kern = time_fn(f_kern, x, sc)
    err = float(jnp.max(jnp.abs(f_kern(x, sc) - f_ref(x, sc))))
    emit(rows, "rmsnorm_ref_4096x1024", t_ref * 1e6, "oracle")
    emit(rows, "rmsnorm_pallas_interp", t_kern * 1e6, f"max_err={err:.1e}")


ALL = [bench_attention, bench_flash_blockskip, bench_wkv6, bench_rmsnorm]
