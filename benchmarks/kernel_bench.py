"""Kernel micro-benchmarks: Pallas (interpret) correctness-path timing vs
pure-jnp reference, plus the blockwise-attention XLA path that the dry-run
memory numbers rest on. On CPU these are *relative* numbers; the derived
column carries the oracle max-error (the deploy gate)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels.ref import ref_attention, ref_rmsnorm, ref_wkv6
from repro.models.blockwise import blockwise_attention_qchunked


def bench_attention(rows):
    key = jax.random.PRNGKey(0)
    b, h, kh, s, d = 1, 8, 2, 1024, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kh, d))
    v = jax.random.normal(ks[2], (b, s, kh, d))

    qT = q.transpose(0, 2, 1, 3)
    kT = k.transpose(0, 2, 1, 3)
    vT = v.transpose(0, 2, 1, 3)
    f_ref = jax.jit(lambda a, b_, c: ref_attention(a, b_, c, causal=True))
    t_ref = time_fn(f_ref, qT, kT, vT)
    f_blk = jax.jit(lambda a, b_, c: blockwise_attention_qchunked(
        a, b_, c, 0, causal=True, block_k=256, block_q=256))
    t_blk = time_fn(f_blk, q, k, v)
    err = float(jnp.max(jnp.abs(
        f_blk(q, k, v) - f_ref(qT, kT, vT).transpose(0, 2, 1, 3))))
    emit(rows, "attn_naive_s1024", t_ref * 1e6, "oracle")
    emit(rows, "attn_blockwise_s1024", t_blk * 1e6,
         f"max_err={err:.1e};ratio={t_blk/t_ref:.2f}")


def bench_wkv6(rows):
    from repro.kernels.ops import wkv6
    key = jax.random.PRNGKey(1)
    b, s, h, p = 1, 512, 4, 64
    ks = jax.random.split(key, 6)
    r, k, v = (jax.random.normal(ks[i], (b, s, h, p)) for i in range(3))
    wlog = -jnp.exp(jax.random.normal(ks[3], (b, s, h, p)) - 0.5)
    u = 0.3 * jax.random.normal(ks[4], (h, p))
    s0 = jnp.zeros((b, h, p, p))
    f_ref = jax.jit(lambda *a: ref_wkv6(*a)[0])
    t_ref = time_fn(f_ref, r, k, v, wlog, u, s0)
    f_kern = jax.jit(lambda *a: wkv6(*a, chunk=32, interpret=True)[0])
    t_kern = time_fn(f_kern, r, k, v, wlog, u, s0)
    err = float(jnp.max(jnp.abs(f_kern(r, k, v, wlog, u, s0)
                                - f_ref(r, k, v, wlog, u, s0))))
    emit(rows, "wkv6_ref_seq_s512", t_ref * 1e6, "oracle(sequential)")
    emit(rows, "wkv6_pallas_interp_s512", t_kern * 1e6,
         f"max_err={err:.1e}")


def bench_rmsnorm(rows):
    from repro.kernels.ops import fused_rmsnorm
    x = jax.random.normal(jax.random.PRNGKey(2), (4096, 1024))
    sc = jnp.ones((1024,))
    f_ref = jax.jit(lambda a, b: ref_rmsnorm(a, b))
    f_kern = jax.jit(lambda a, b: fused_rmsnorm(a, b, interpret=True))
    t_ref = time_fn(f_ref, x, sc)
    t_kern = time_fn(f_kern, x, sc)
    err = float(jnp.max(jnp.abs(f_kern(x, sc) - f_ref(x, sc))))
    emit(rows, "rmsnorm_ref_4096x1024", t_ref * 1e6, "oracle")
    emit(rows, "rmsnorm_pallas_interp", t_kern * 1e6, f"max_err={err:.1e}")


ALL = [bench_attention, bench_wkv6, bench_rmsnorm]
