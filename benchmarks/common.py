"""Shared benchmark utilities: measured CPU step times + the analytic
cluster model (comm_model) that turns them into the paper's scaling figures.

The hardware gate (GPU clusters) is simulated per the brief: per-device
compute time is MEASURED (reduced ViT on this host, scaled by the target
GPU's throughput ratio), synchronization is MODELED (ring all-reduce over
the cluster interconnect), heterogeneity via per-device speed vectors.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from repro.configs import EngineConfig, get_smoke_config
from repro.core.engine import DistributedEngine
from repro.data import DATASETS, DataPipeline
from repro.launch.mesh import make_local_mesh

# paper cluster interconnects (B/s)
ETHERNET_10G = 1.25e9          # Tesla lab cluster
NVLINK_NODE = 5e10             # intra-node Vector
IB_25G = 3.125e9               # inter-node Vector


def child_env(devices: int) -> dict:
    """Subprocess env for an N-host-device CPU child (host device count is
    fixed at jax init, so multi-device sweeps fork children) — one place
    for the XLA_FLAGS/JAX_PLATFORMS/PYTHONPATH recipe shared by the
    scaling/prefetch benches and the ckpt-size table."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    return env


_CACHE = {}


def vit_step_time_and_bytes(batch: int = 16, steps: int = 5):
    """Measured wall-clock per train step of the reduced ViT on this host,
    plus its gradient byte count (fp32) for the all-reduce model."""
    key = ("vit", batch)
    if key in _CACHE:
        return _CACHE[key]
    cfg = get_smoke_config("vit-b16").replace(dtype="float32")
    mesh = make_local_mesh()
    eng = DistributedEngine(cfg, EngineConfig(train_batch_size=batch,
                                              total_steps=100), mesh)
    pipe = DataPipeline(kind="image", global_batch=batch,
                        dataset=DATASETS["cifar10"],
                        resolution=cfg.image_size)
    state = eng.init_state(seed=0)
    step = eng.jit_train_step(donate=False)
    it = iter(pipe.batches())
    b0 = jax.tree.map(jnp.asarray, next(it))
    with mesh:
        step(state, b0)[1]["loss"].block_until_ready()
        t0 = time.perf_counter()
        for i in range(steps):
            state, m = step(state, b0)
        m["loss"].block_until_ready()
    dt = (time.perf_counter() - t0) / steps
    grad_bytes = 4 * cfg.param_count()
    _CACHE[key] = (dt, grad_bytes)
    return dt, grad_bytes


def scale_to_gpu(cpu_time: float, batch: int, gpu_flops: float = 8.1e12,
                 cpu_flops: float = 5e10) -> float:
    """Translate measured CPU step time to a target GPU (default T4) via
    peak-throughput ratio — the simulation knob documented in DESIGN.md."""
    return cpu_time * cpu_flops / gpu_flops


def time_fn(fn, *args, iters: int = 10, warmup: int = 2):
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def emit(rows, name, us, derived):
    rows.append(f"{name},{us:.2f},{derived}")
