"""Real-image datasets: CIFAR-10/100 sources with train/test splits.

The paper's experiments are real CIFAR-10/100 training runs; this module
puts them behind the same **cursor-addressable** contract the synthetic
pipeline established (``batch_at(epoch, index)`` pure in
``(seed, epoch, index)``), so the TrainState data cursor and the elastic
resume path work unchanged on the real workload.

Two backing stores, one interface:

- **Disk** (``data_dir`` given and the binary batches exist): the standard
  python-pickle distributions — ``cifar-10-batches-py/data_batch_{1..5}`` +
  ``test_batch``, or ``cifar-100-python/{train,test}`` — loaded once into
  host memory, per-channel normalized with the canonical mean/std.
- **Procedural** (no ``data_dir``; the CI/test path — never downloads):
  a deterministic CIFAR-*like* generator. Train batches are pure in the
  batch seed (class template + structured noise, same construction as
  ``data/synthetic.py`` so accuracy trends are learnable); the eval split
  is a FIXED finite array generated from the source seed alone, so every
  process/layout sees byte-identical eval data.

Evaluation iterates the test split in order; the final non-divisible batch
is zero-padded to the full batch shape with a ``mask`` leaf (1 = real
example) so the jitted eval step sees one static shape and the padding
contributes nothing to the metric counts.
"""
from __future__ import annotations

import os
import pickle
from typing import Iterator, Optional

import numpy as np

from repro.data.synthetic import DATASETS, DatasetSpec, \
    class_conditional_images

# canonical per-channel statistics (pytorch-image-models conventions)
CIFAR10_MEAN = (0.4914, 0.4822, 0.4465)
CIFAR10_STD = (0.2470, 0.2435, 0.2616)
CIFAR100_MEAN = (0.5071, 0.4865, 0.4409)
CIFAR100_STD = (0.2673, 0.2564, 0.2762)

_STATS = {"cifar10": (CIFAR10_MEAN, CIFAR10_STD),
          "cifar100": (CIFAR100_MEAN, CIFAR100_STD)}

# procedural split sizes: big enough for meaningful accuracy, small enough
# that CI materializes the eval split in milliseconds
PROCEDURAL_TRAIN_SIZE = 4096
PROCEDURAL_EVAL_SIZE = 500


def _pickle_load(path: str) -> dict:
    with open(path, "rb") as f:
        d = pickle.load(f, encoding="bytes")
    return {k.decode() if isinstance(k, bytes) else k: v
            for k, v in d.items()}


def _find_cifar_files(name: str, data_dir: str):
    """Locate the pickle batches under ``data_dir`` (or the standard
    subdirectory the archives unpack into). Returns (train_files,
    test_file, label_key) or None when absent."""
    sub = "cifar-10-batches-py" if name == "cifar10" else "cifar-100-python"
    for root in (os.path.join(data_dir, sub), data_dir):
        if name == "cifar10":
            train = [os.path.join(root, f"data_batch_{i}")
                     for i in range(1, 6)]
            test = os.path.join(root, "test_batch")
            key = "labels"
        else:
            train = [os.path.join(root, "train")]
            test = os.path.join(root, "test")
            key = "fine_labels"
        if all(os.path.isfile(p) for p in train) and os.path.isfile(test):
            return train, test, key
    return None


def _load_split(files, label_key: str):
    imgs, labels = [], []
    for path in files:
        d = _pickle_load(path)
        data = np.asarray(d["data"], np.uint8)
        # (N, 3072) row-major CHW -> (N, 32, 32, 3) HWC
        imgs.append(data.reshape(-1, 3, 32, 32).transpose(0, 2, 3, 1))
        labels.append(np.asarray(d[label_key], np.int64))
    return np.concatenate(imgs), np.concatenate(labels)


def normalize_images(u8, mean, std):
    """uint8 HWC -> float32 normalized with per-channel statistics."""
    x = np.asarray(u8, np.float32) / 255.0
    return (x - np.asarray(mean, np.float32)) \
        / np.asarray(std, np.float32)


def _upsample(images: np.ndarray, res: int) -> np.ndarray:
    """Nearest-neighbor upsample 32px CIFAR to the model resolution (the
    full ViT-B/16 trains at 224 = 7 x 32)."""
    native = images.shape[1]
    if res == native:
        return images
    if res % native:
        raise ValueError(
            f"target resolution {res} not an integer multiple of the "
            f"native {native}px CIFAR grid")
    k = res // native
    return np.repeat(np.repeat(images, k, axis=1), k, axis=2)


class CIFARSource:
    """CIFAR-10/100 train/test source behind the cursor contract.

    ``train_batch(batch, seed=...)`` is pure in ``seed`` — the pipeline
    derives that seed from ``(source seed, epoch, index)`` via
    ``batch_seed``, which is the whole addressability story. ``eval_*``
    expose the fixed test split for the sharded eval loop.
    """

    def __init__(self, name: str = "cifar10", *,
                 data_dir: Optional[str] = None, seed: int = 0,
                 resolution: Optional[int] = None,
                 train_size: Optional[int] = None,
                 eval_size: Optional[int] = None):
        if name not in _STATS:
            raise ValueError(f"unknown CIFAR dataset {name!r}; "
                             f"expected one of {sorted(_STATS)}")
        self.spec: DatasetSpec = DATASETS[name]
        self.name = name
        self.seed = seed
        self.resolution = resolution or self.spec.resolution
        self.mean, self.std = _STATS[name]

        found = _find_cifar_files(name, data_dir) if data_dir else None
        if data_dir and found is None:
            # an EXPLICIT data_dir that doesn't hold the batches is a
            # user error, not a fallback: silently training on procedural
            # data while reporting plausible metrics would be the worst
            # possible failure mode for a paper-reproduction run
            sub = "cifar-10-batches-py" if name == "cifar10" \
                else "cifar-100-python"
            raise FileNotFoundError(
                f"--data-dir {data_dir!r} does not contain the {name} "
                f"pickle batches (expected {sub}/ there or the batch "
                f"files directly); unset it to use the procedural "
                f"generator")
        self.procedural = found is None
        if found is not None:
            train_files, test_file, key = found
            ti, tl = _load_split(train_files, key)
            ei, el = _load_split([test_file], key)
            self._train_images = normalize_images(ti, self.mean, self.std)
            self._train_labels = tl.astype(np.int32)
            self._eval_images = normalize_images(ei, self.mean, self.std)
            self._eval_labels = el.astype(np.int32)
            if train_size:
                self._train_images = self._train_images[:train_size]
                self._train_labels = self._train_labels[:train_size]
            if eval_size:
                self._eval_images = self._eval_images[:eval_size]
                self._eval_labels = self._eval_labels[:eval_size]
        else:
            self._train_images = self._train_labels = None
            n_eval = eval_size or PROCEDURAL_EVAL_SIZE
            self._train_size = train_size or PROCEDURAL_TRAIN_SIZE
            # fixed eval split: pure in (name, seed) — every process and
            # every layout sees byte-identical eval data
            self._eval_images, self._eval_labels = self._procedural_examples(
                np.random.default_rng((self.seed, 0xE7A1)), n_eval)

    # ------------------------------------------------------------------
    # procedural generator (CI path — no downloads)
    # ------------------------------------------------------------------

    def _procedural_examples(self, rng: np.random.Generator, n: int):
        """Class-conditional images at the *native* 32px grid, already
        normalized-scale (templates + noise have ~unit variance) — the
        shared synthetic generator, so the procedural splits stay
        learnable the same way the legacy stream is."""
        return class_conditional_images(self.spec, n, rng, resolution=32)

    # ------------------------------------------------------------------
    # train split (cursor-addressable via the pipeline's batch seed)
    # ------------------------------------------------------------------

    @property
    def train_size(self) -> int:
        if self.procedural:
            return self._train_size
        return len(self._train_labels)

    def train_batch(self, batch: int, *, seed: int) -> dict:
        """One un-augmented train batch, pure in ``seed``. Disk mode draws
        a with-replacement sample of the split (the DataLoader-with-
        shuffle equivalent, but addressable); procedural mode synthesizes
        the batch from the seed directly."""
        rng = np.random.default_rng(seed)
        if self.procedural:
            images, labels = self._procedural_examples(rng, batch)
        else:
            idx = rng.integers(0, len(self._train_labels), (batch,))
            images, labels = self._train_images[idx], self._train_labels[idx]
        return {"images": _upsample(images, self.resolution),
                "labels": labels}

    # ------------------------------------------------------------------
    # eval split (fixed, finite, padded to a static batch shape)
    # ------------------------------------------------------------------

    @property
    def eval_size(self) -> int:
        return len(self._eval_labels)

    def eval_batches(self, batch: int) -> Iterator[dict]:
        """Iterate the test split in order. Every yielded batch has the
        full static shape; the final non-divisible batch is zero-padded
        with ``mask`` zeros (the eval step multiplies every per-example
        indicator by the mask, so padding is metric-invisible).
        Upsampling happens per batch: at 224px the full upsampled CIFAR
        test split would be ~6 GB of host fp32 per eval invocation."""
        labels = self._eval_labels
        n = len(labels)
        for lo in range(0, n, batch):
            hi = min(lo + batch, n)
            m = hi - lo
            img = _upsample(self._eval_images[lo:hi], self.resolution)
            lab = labels[lo:hi]
            mask = np.ones((batch,), np.float32)
            if m < batch:
                pad = batch - m
                img = np.concatenate(
                    [img, np.zeros((pad,) + img.shape[1:], img.dtype)])
                lab = np.concatenate([lab, np.zeros((pad,), lab.dtype)])
                mask[m:] = 0.0
            yield {"images": img, "labels": lab, "mask": mask}

    def num_eval_batches(self, batch: int) -> int:
        return -(-self.eval_size // batch)


def make_source(dataset: str, *, data_dir: Optional[str] = None,
                seed: int = 0, resolution: Optional[int] = None,
                eval_size: Optional[int] = None) -> Optional[CIFARSource]:
    """``None`` for the synthetic tensor workload, a CIFARSource otherwise
    (the one switch ``launch/train.py`` flips on ``--dataset``)."""
    if dataset == "synthetic":
        return None
    return CIFARSource(dataset, data_dir=data_dir, seed=seed,
                       resolution=resolution, eval_size=eval_size)
