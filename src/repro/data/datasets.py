"""Real-image datasets: CIFAR-10/100 sources with train/test splits.

The paper's experiments are real CIFAR-10/100 training runs; this module
puts them behind the same **cursor-addressable** contract the synthetic
pipeline established (``batch_at(epoch, index)`` pure in
``(seed, epoch, index)``), so the TrainState data cursor and the elastic
resume path work unchanged on the real workload.

Images are **uint8 end-to-end on the host** (the timm-PrefetchLoader
idiom): load, store, slice, and transfer all happen at the native 8-bit
resolution, and normalization (mean/std), the upsample to the model
resolution, and the fp32 cast all run **on device inside the jitted step**
(``data/augment.py: device_preprocess``). That cuts host->device bytes 4x
versus shipping pre-normalized fp32 — at 224px it also keeps the 196x
larger upsampled fp32 image off the host entirely. Each source exposes a
:class:`Preproc` carrying the statistics the device-side half needs.

Two backing stores, one interface:

- **Disk** (``data_dir`` given and the binary batches exist): the standard
  python-pickle distributions — ``cifar-10-batches-py/data_batch_{1..5}`` +
  ``test_batch``, or ``cifar-100-python/{train,test}`` — loaded once into
  host memory as raw uint8 (a 4x smaller resident split than the old
  pre-normalized fp32 copies).
- **Procedural** (no ``data_dir``; the CI/test path — never downloads):
  a deterministic CIFAR-*like* generator. Train batches are pure in the
  batch seed (class template + structured noise, same construction as
  ``data/synthetic.py`` so accuracy trends are learnable), quantized to
  uint8 through the inverse of the canonical normalization; the eval split
  is a FIXED finite uint8 array generated from the source seed alone, so
  every process/layout sees byte-identical eval data.

Evaluation iterates the test split in order; the final non-divisible batch
is zero-padded to the full batch shape with a ``mask`` leaf (1 = real
example) so the jitted eval step sees one static shape and the padding
contributes nothing to the metric counts.

Weak scaling (the paper's §IV-A protocol): each world size trains on a
*proportional subset* of the split. ``train_batch(..., pool=p)`` restricts
the sampled index pool to the first ``p`` examples — ``DataPipeline``
derives ``p`` from ``weak_scaling_frac``, so shrinking ``epoch_size``
alone (the old, silently-wrong behavior) no longer stands in for
restricting the data actually sampled.
"""
from __future__ import annotations

import os
import pickle
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro.data.synthetic import DATASETS, DatasetSpec, \
    class_conditional_images

# canonical per-channel statistics (pytorch-image-models conventions)
CIFAR10_MEAN = (0.4914, 0.4822, 0.4465)
CIFAR10_STD = (0.2470, 0.2435, 0.2616)
CIFAR100_MEAN = (0.5071, 0.4865, 0.4409)
CIFAR100_STD = (0.2673, 0.2564, 0.2762)

_STATS = {"cifar10": (CIFAR10_MEAN, CIFAR10_STD),
          "cifar100": (CIFAR100_MEAN, CIFAR100_STD)}

# procedural split sizes: big enough for meaningful accuracy, small enough
# that CI materializes the eval split in milliseconds
PROCEDURAL_TRAIN_SIZE = 4096
PROCEDURAL_EVAL_SIZE = 500


@dataclass(frozen=True)
class Preproc:
    """What the device-side half of the data path needs to finish a uint8
    batch: the normalization statistics and the native pixel grid the
    uint8 images are stored at. Hashable, so it is jit-safe as a closure
    constant of the compiled step."""
    mean: tuple
    std: tuple
    native_resolution: int


def _pickle_load(path: str) -> dict:
    with open(path, "rb") as f:
        d = pickle.load(f, encoding="bytes")
    return {k.decode() if isinstance(k, bytes) else k: v
            for k, v in d.items()}


def _find_cifar_files(name: str, data_dir: str):
    """Locate the pickle batches under ``data_dir`` (or the standard
    subdirectory the archives unpack into). Returns (train_files,
    test_file, label_key) or None when absent."""
    sub = "cifar-10-batches-py" if name == "cifar10" else "cifar-100-python"
    for root in (os.path.join(data_dir, sub), data_dir):
        if name == "cifar10":
            train = [os.path.join(root, f"data_batch_{i}")
                     for i in range(1, 6)]
            test = os.path.join(root, "test_batch")
            key = "labels"
        else:
            train = [os.path.join(root, "train")]
            test = os.path.join(root, "test")
            key = "fine_labels"
        if all(os.path.isfile(p) for p in train) and os.path.isfile(test):
            return train, test, key
    return None


def _load_split(files, label_key: str):
    imgs, labels = [], []
    for path in files:
        d = _pickle_load(path)
        data = np.asarray(d["data"], np.uint8)
        # (N, 3072) row-major CHW -> (N, 32, 32, 3) HWC
        imgs.append(data.reshape(-1, 3, 32, 32).transpose(0, 2, 3, 1))
        labels.append(np.asarray(d[label_key], np.int64))
    return np.concatenate(imgs), np.concatenate(labels)


def normalize_images(u8, mean, std):
    """uint8 HWC -> float32 normalized with per-channel statistics. The
    HOST-side reference implementation: the jitted step applies the same
    map on device (data/augment.normalize), and the parity test pins the
    two to fp32 tolerance."""
    x = np.asarray(u8, np.float32) / 255.0
    return (x - np.asarray(mean, np.float32)) \
        / np.asarray(std, np.float32)


def quantize_images(x, mean, std):
    """Inverse of :func:`normalize_images`: normalized-scale fp32 ->
    uint8. Used to store the procedural splits in the same raw-byte form
    the disk pickles arrive in (values beyond the representable range
    clip; the ~1/255 quantization step is below the generator's noise
    floor, so learnability is unaffected)."""
    u = (np.asarray(x, np.float32) * np.asarray(std, np.float32)
         + np.asarray(mean, np.float32)) * 255.0
    return np.clip(np.rint(u), 0, 255).astype(np.uint8)


def _upsample(images: np.ndarray, res: int) -> np.ndarray:
    """Nearest-neighbor upsample to the model resolution — HOST-side
    reference only (the hot path upsamples on device; this stays as the
    oracle the uint8-path parity tests compare against)."""
    native = images.shape[1]
    if res == native:
        return images
    if res % native:
        raise ValueError(
            f"target resolution {res} not an integer multiple of the "
            f"native {native}px grid")
    k = res // native
    return np.repeat(np.repeat(images, k, axis=1), k, axis=2)


def padded_eval_batches(images: np.ndarray, labels: np.ndarray,
                        batch: int) -> Iterator[dict]:
    """Iterate a finite eval split in order at one static batch shape:
    the final non-divisible batch is zero-padded with ``mask`` zeros (the
    eval step multiplies every per-example indicator by the mask, so
    padding is metric-invisible). Shared by the in-RAM CIFAR source and
    the sharded streaming source."""
    n = len(labels)
    for lo in range(0, n, batch):
        hi = min(lo + batch, n)
        m = hi - lo
        img = images[lo:hi]
        lab = labels[lo:hi]
        mask = np.ones((batch,), np.float32)
        if m < batch:
            pad = batch - m
            img = np.concatenate(
                [img, np.zeros((pad,) + img.shape[1:], img.dtype)])
            lab = np.concatenate([lab, np.zeros((pad,), lab.dtype)])
            mask[m:] = 0.0
        yield {"images": img, "labels": lab, "mask": mask}


def _check_pool(pool: Optional[int], size: int) -> int:
    if pool is None:
        return size
    if not 0 < pool <= size:
        raise ValueError(
            f"sample pool {pool} out of range for a split of {size} "
            f"examples")
    return pool


class CIFARSource:
    """CIFAR-10/100 train/test source behind the cursor contract.

    ``train_batch(batch, seed=...)`` is pure in ``seed`` — the pipeline
    derives that seed from ``(source seed, epoch, index)`` via
    ``batch_seed``, which is the whole addressability story. ``eval_*``
    expose the fixed test split for the sharded eval loop. Both splits
    live (and leave) as uint8 at the native 32px grid; ``preproc`` names
    the on-device normalization/upsample that completes the batch.
    """

    def __init__(self, name: str = "cifar10", *,
                 data_dir: Optional[str] = None, seed: int = 0,
                 resolution: Optional[int] = None,
                 train_size: Optional[int] = None,
                 eval_size: Optional[int] = None):
        if name not in _STATS:
            raise ValueError(f"unknown CIFAR dataset {name!r}; "
                             f"expected one of {sorted(_STATS)}")
        self.spec: DatasetSpec = DATASETS[name]
        self.name = name
        self.seed = seed
        self.native_resolution = 32
        self.resolution = resolution or self.spec.resolution
        if self.resolution % self.native_resolution:
            raise ValueError(
                f"model resolution {self.resolution} not an integer "
                f"multiple of the native {self.native_resolution}px "
                f"CIFAR grid")
        self.mean, self.std = _STATS[name]

        found = _find_cifar_files(name, data_dir) if data_dir else None
        if data_dir and found is None:
            # an EXPLICIT data_dir that doesn't hold the batches is a
            # user error, not a fallback: silently training on procedural
            # data while reporting plausible metrics would be the worst
            # possible failure mode for a paper-reproduction run
            sub = "cifar-10-batches-py" if name == "cifar10" \
                else "cifar-100-python"
            raise FileNotFoundError(
                f"--data-dir {data_dir!r} does not contain the {name} "
                f"pickle batches (expected {sub}/ there or the batch "
                f"files directly); unset it to use the procedural "
                f"generator")
        self.procedural = found is None
        if found is not None:
            train_files, test_file, key = found
            # raw uint8 splits — never a whole-split fp32 copy
            ti, tl = _load_split(train_files, key)
            ei, el = _load_split([test_file], key)
            self._train_images = ti
            self._train_labels = tl.astype(np.int32)
            self._eval_images = ei
            self._eval_labels = el.astype(np.int32)
            if train_size:
                self._train_images = self._train_images[:train_size]
                self._train_labels = self._train_labels[:train_size]
            if eval_size:
                self._eval_images = self._eval_images[:eval_size]
                self._eval_labels = self._eval_labels[:eval_size]
        else:
            self._train_images = self._train_labels = None
            n_eval = eval_size or PROCEDURAL_EVAL_SIZE
            self._train_size = train_size or PROCEDURAL_TRAIN_SIZE
            # fixed eval split: pure in (name, seed) — every process and
            # every layout sees byte-identical eval data
            self._eval_images, self._eval_labels = self._procedural_examples(
                np.random.default_rng((self.seed, 0xE7A1)), n_eval)

    @property
    def preproc(self) -> Preproc:
        return Preproc(mean=self.mean, std=self.std,
                       native_resolution=self.native_resolution)

    # ------------------------------------------------------------------
    # procedural generator (CI path — no downloads)
    # ------------------------------------------------------------------

    def _procedural_examples(self, rng: np.random.Generator, n: int):
        """Class-conditional uint8 images at the *native* 32px grid: the
        shared synthetic generator emits normalized-scale fp32 (templates
        + noise, ~unit variance), quantized here through the inverse
        normalization so the stored bytes look exactly like the disk
        pickles — and normalizing them on device recovers the learnable
        signal."""
        x, labels = class_conditional_images(self.spec, n, rng,
                                             resolution=32)
        return quantize_images(x, self.mean, self.std), labels

    # ------------------------------------------------------------------
    # train split (cursor-addressable via the pipeline's batch seed)
    # ------------------------------------------------------------------

    @property
    def train_size(self) -> int:
        if self.procedural:
            return self._train_size
        return len(self._train_labels)

    def train_batch(self, batch: int, *, seed: int,
                    pool: Optional[int] = None) -> dict:
        """One un-augmented uint8 train batch, pure in ``seed``. Disk mode
        draws a with-replacement sample of the split (the DataLoader-with-
        shuffle equivalent, but addressable); procedural mode synthesizes
        the batch from the seed directly.

        ``pool`` restricts the sampled index pool to the first ``pool``
        examples — the §IV-A weak-scaling protocol, where each world size
        trains on a proportional subset of the real split. The procedural
        stream has no finite example identity, so there ``pool`` only
        validates (the epoch bound already shrinks with the fraction)."""
        rng = np.random.default_rng(seed)
        _check_pool(pool, self.train_size)
        if self.procedural:
            images, labels = self._procedural_examples(rng, batch)
        else:
            idx = rng.integers(0, pool or len(self._train_labels), (batch,))
            images, labels = self._train_images[idx], self._train_labels[idx]
        return {"images": images, "labels": labels}

    # ------------------------------------------------------------------
    # eval split (fixed, finite, padded to a static batch shape)
    # ------------------------------------------------------------------

    @property
    def eval_size(self) -> int:
        return len(self._eval_labels)

    def eval_batches(self, batch: int) -> Iterator[dict]:
        """Iterate the test split in order, uint8 at the native grid (the
        on-device preprocess upsamples + normalizes — at 224px the old
        host-side fp32 upsample materialized ~6 GB per eval invocation)."""
        return padded_eval_batches(self._eval_images, self._eval_labels,
                                   batch)

    def num_eval_batches(self, batch: int) -> int:
        return -(-self.eval_size // batch)


def make_source(dataset: str, *, data_dir: Optional[str] = None,
                seed: int = 0, resolution: Optional[int] = None,
                train_size: Optional[int] = None,
                eval_size: Optional[int] = None,
                shard_dir: Optional[str] = None):
    """``None`` for the synthetic tensor workload, a data source otherwise
    (the one switch ``launch/train.py`` flips on ``--dataset``).

    ``shard_dir`` takes precedence: it names a webdataset-style shard
    directory (``data/streaming.py``) and returns a
    :class:`~repro.data.streaming.ShardedSource` — the ImageNet-class
    path that streams shards instead of materializing a split in RAM."""
    if shard_dir:
        from repro.data.streaming import ShardedSource
        return ShardedSource(shard_dir, seed=seed, resolution=resolution,
                             train_size=train_size, eval_size=eval_size)
    if dataset == "synthetic":
        return None
    return CIFARSource(dataset, data_dir=data_dir, seed=seed,
                       resolution=resolution, train_size=train_size,
                       eval_size=eval_size)
