"""Deterministic synthetic datasets with the exact shapes/cardinalities of
the paper's datasets (Table I). The data gate (CIFAR/ImageNet downloads) is
simulated per the brief: images are seeded pseudo-random with class-dependent
structure so accuracy curves are learnable (the paper's Fig. 7/10 trends),
labels are balanced.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    num_classes: int
    num_images: int
    resolution: int

    @property
    def channels(self):
        return 3


# Paper Table I
DATASETS = {
    "cifar10": DatasetSpec("cifar10", 10, 60_000, 32),
    "cifar100": DatasetSpec("cifar100", 100, 60_000, 32),
    "imagenet100": DatasetSpec("imagenet100", 100, 100_000, 224),
}


def class_conditional_images(spec: DatasetSpec, n: int,
                             rng: np.random.Generator,
                             resolution: int | None = None):
    """Class-conditional synthetic images: per-class fixed template +
    noise, learnable by a linear probe. Draw order (labels, then noise)
    is a compatibility contract — `make_image_batch` streams and the
    procedural CIFAR splits (data/datasets.py) both derive from it."""
    res = resolution or spec.resolution
    labels = rng.integers(0, spec.num_classes, (n,))
    # fixed per-class templates (seeded independently of the stream rng)
    trng = np.random.default_rng(1234)
    templates = trng.normal(0, 1, (spec.num_classes, 8, 8, 3)).astype(
        np.float32)
    up = templates[labels]
    reps = res // 8 + 1
    up = np.tile(up, (1, reps, reps, 1))[:, :res, :res]
    noise = rng.normal(0, 0.7, (n, res, res, 3)).astype(np.float32)
    return (up + noise).astype(np.float32), labels.astype(np.int32)


def make_image_batch(spec: DatasetSpec, batch: int, *, seed: int,
                     resolution: int | None = None):
    """One seeded batch of class-conditional images (train-accuracy
    trends are meaningful)."""
    images, labels = class_conditional_images(
        spec, batch, np.random.default_rng(seed), resolution)
    return {"images": images, "labels": labels}


def make_token_batch(vocab: int, batch: int, seq: int, *, seed: int):
    rng = np.random.default_rng(seed)
    # order-2 markov-ish stream: learnable next-token structure
    base = rng.integers(0, vocab, (batch, seq))
    shifted = np.roll(base, 1, axis=1)
    mix = rng.random((batch, seq)) < 0.5
    toks = np.where(mix, (shifted * 31 + 7) % vocab, base)
    return {"tokens": toks.astype(np.int32)}
