"""Sharded streaming dataset source: webdataset-style npz shards behind
the ``batch_at(epoch, index)`` cursor contract.

The in-RAM :class:`~repro.data.datasets.CIFARSource` stops scaling at
ImageNet-class inputs (the ``imagenet100`` spec is 100k x 224px — ~15 GB
even as uint8). This module stores a dataset as a directory of fixed-size
**uint8 npz shards** plus a JSON manifest, and serves batches by global
example index through a small LRU shard cache — resident memory is
``cache_shards * shard_size`` examples regardless of dataset size.

Layout (``shards.json`` + ``{split}-{NNNNN}.npz``)::

    shards.json                 manifest: schema tag, dataset identity,
                                normalization stats, per-split shard
                                names/sizes (the global index -> shard
                                mapping is the running sum of sizes)
    train-00000.npz ...         images (N, r, r, 3) uint8, labels (N,) i32
    eval-00000.npz ...

Determinism contract: ``train_batch(batch, seed=...)`` draws global
indices from ``default_rng(seed)`` exactly like the in-RAM disk source, so
a batch is pure in ``(seed,)`` **and independent of sharding geometry** —
re-sharding the same examples at a different ``shard_size`` replays the
identical stream, and elastic resume works unchanged (regression-tested
across a shard boundary in ``tests/test_streaming.py``).

``python -m repro.data.streaming --out DIR ...`` writes a shard set from a
:class:`CIFARSource` (procedural by default — the CI path; ``--data-dir``
shards the real pickles).
"""
from __future__ import annotations

import argparse
import json
import os
from collections import OrderedDict
from typing import Iterator, Optional

import numpy as np

from repro.data.datasets import CIFARSource, Preproc, _check_pool, \
    padded_eval_batches
from repro.data.synthetic import DATASETS, DatasetSpec

SCHEMA = "repro-shards/v1"
MANIFEST = "shards.json"
DEFAULT_SHARD_SIZE = 1024


def _write_split(out_dir: str, split: str, images: np.ndarray,
                 labels: np.ndarray, shard_size: int):
    names, sizes = [], []
    for i, lo in enumerate(range(0, len(labels), shard_size)):
        hi = min(lo + shard_size, len(labels))
        name = f"{split}-{i:05d}.npz"
        np.savez(os.path.join(out_dir, name),
                 images=np.ascontiguousarray(images[lo:hi], np.uint8),
                 labels=np.asarray(labels[lo:hi], np.int32))
        names.append(name)
        sizes.append(hi - lo)
    return {"shards": names, "sizes": sizes, "total": int(len(labels))}


def write_shards(out_dir: str, source: CIFARSource, *,
                 shard_size: int = DEFAULT_SHARD_SIZE) -> dict:
    """Materialize a CIFARSource's splits as a shard directory.

    A procedural source has no stored train split — it is materialized
    once here, pure in the source seed (so two writers with the same seed
    produce byte-identical shard sets). Returns the manifest dict."""
    if shard_size < 1:
        raise ValueError(f"shard_size must be >= 1: {shard_size}")
    os.makedirs(out_dir, exist_ok=True)
    if source.procedural:
        rng = np.random.default_rng((source.seed, 0x5A4D))
        train_images, train_labels = source._procedural_examples(
            rng, source.train_size)
    else:
        train_images = source._train_images
        train_labels = source._train_labels
    manifest = {
        "schema": SCHEMA,
        "dataset": source.name,
        "num_classes": source.spec.num_classes,
        "resolution": source.native_resolution,
        "mean": list(source.mean),
        "std": list(source.std),
        "splits": {
            "train": _write_split(out_dir, "train", train_images,
                                  train_labels, shard_size),
            "eval": _write_split(out_dir, "eval", source._eval_images,
                                 source._eval_labels, shard_size),
        },
    }
    with open(os.path.join(out_dir, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


class ShardedSource:
    """Shard-directory dataset source, API-compatible with ``CIFARSource``
    (``train_batch``/``eval_batches``/``preproc``/``spec``/sizes), so the
    pipeline, engine, and eval loop run on it unchanged.

    Shards load lazily through an LRU cache of ``cache_shards`` entries;
    a gathered batch groups its indices by shard, so with-replacement
    sampling touches at most ``batch`` shards and usually far fewer.
    """

    def __init__(self, shard_dir: str, *, seed: int = 0,
                 resolution: Optional[int] = None,
                 train_size: Optional[int] = None,
                 eval_size: Optional[int] = None, cache_shards: int = 4):
        path = os.path.join(shard_dir, MANIFEST)
        if not os.path.isfile(path):
            raise FileNotFoundError(
                f"--shard-dir {shard_dir!r} has no {MANIFEST}; write one "
                f"with `python -m repro.data.streaming --out {shard_dir}`")
        with open(path) as f:
            m = json.load(f)
        if m.get("schema") != SCHEMA:
            raise ValueError(
                f"unsupported shard manifest schema {m.get('schema')!r} "
                f"in {path} (expected {SCHEMA!r})")
        self.dir = shard_dir
        self.name = m["dataset"]
        self.seed = seed
        self.spec: DatasetSpec = DATASETS.get(
            self.name,
            DatasetSpec(self.name, m["num_classes"], 0, m["resolution"]))
        self.native_resolution = int(m["resolution"])
        self.resolution = resolution or max(self.spec.resolution,
                                            self.native_resolution)
        if self.resolution % self.native_resolution:
            raise ValueError(
                f"model resolution {self.resolution} not an integer "
                f"multiple of the native {self.native_resolution}px grid")
        self.mean = tuple(m["mean"])
        self.std = tuple(m["std"])
        self.procedural = False
        self._splits = m["splits"]
        # start offset of each shard = exclusive running sum of sizes
        self._starts = {
            split: np.concatenate(
                [[0], np.cumsum(s["sizes"])[:-1]]).astype(np.int64)
            for split, s in self._splits.items()}
        self.train_size = min(train_size or self._splits["train"]["total"],
                              self._splits["train"]["total"])
        self.eval_size = min(eval_size or self._splits["eval"]["total"],
                             self._splits["eval"]["total"])
        self._cache: OrderedDict = OrderedDict()
        self._cache_shards = max(1, cache_shards)

    @property
    def preproc(self) -> Preproc:
        return Preproc(mean=self.mean, std=self.std,
                       native_resolution=self.native_resolution)

    # ------------------------------------------------------------------
    # shard access
    # ------------------------------------------------------------------

    def _shard(self, split: str, i: int):
        key = (split, i)
        if key in self._cache:
            self._cache.move_to_end(key)
            return self._cache[key]
        name = self._splits[split]["shards"][i]
        with np.load(os.path.join(self.dir, name)) as z:
            pair = (np.asarray(z["images"], np.uint8),
                    np.asarray(z["labels"], np.int32))
        self._cache[key] = pair
        if len(self._cache) > self._cache_shards:
            self._cache.popitem(last=False)
        return pair

    def _gather(self, split: str, idx: np.ndarray):
        """Examples at GLOBAL indices ``idx`` (original order preserved),
        loading each touched shard once."""
        idx = np.asarray(idx, np.int64)
        starts = self._starts[split]
        sid = np.searchsorted(starts, idx, side="right") - 1
        r = self.native_resolution
        images = np.empty((len(idx), r, r, 3), np.uint8)
        labels = np.empty((len(idx),), np.int32)
        for s in np.unique(sid):
            imgs, labs = self._shard(split, int(s))
            sel = sid == s
            local = idx[sel] - starts[s]
            images[sel] = imgs[local]
            labels[sel] = labs[local]
        return images, labels

    # ------------------------------------------------------------------
    # the CIFARSource interface
    # ------------------------------------------------------------------

    def train_batch(self, batch: int, *, seed: int,
                    pool: Optional[int] = None) -> dict:
        """Pure in ``seed`` and sharding-geometry-invariant: indices are
        drawn over the GLOBAL example range exactly like the in-RAM disk
        source, then resolved through the shard map. ``pool`` restricts
        the sampled range (§IV-A weak scaling)."""
        rng = np.random.default_rng(seed)
        limit = _check_pool(pool, self.train_size)
        idx = rng.integers(0, limit, (batch,))
        images, labels = self._gather("train", idx)
        return {"images": images, "labels": labels}

    def eval_batches(self, batch: int) -> Iterator[dict]:
        """Iterate the eval split in order at one static padded batch
        shape — one gathered chunk per yielded batch, so only the shards
        under the current window are resident."""
        for lo in range(0, self.eval_size, batch):
            hi = min(lo + batch, self.eval_size)
            images, labels = self._gather("eval", np.arange(lo, hi))
            yield from padded_eval_batches(images, labels, batch)

    def num_eval_batches(self, batch: int) -> int:
        return -(-self.eval_size // batch)


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="write a repro-shards/v1 shard directory from a "
                    "CIFAR source (procedural unless --data-dir holds "
                    "the real pickles)")
    ap.add_argument("--out", required=True, help="shard directory to write")
    ap.add_argument("--dataset", default="cifar10",
                    choices=["cifar10", "cifar100"])
    ap.add_argument("--data-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--train-size", type=int, default=None)
    ap.add_argument("--eval-size", type=int, default=None)
    ap.add_argument("--shard-size", type=int, default=DEFAULT_SHARD_SIZE)
    args = ap.parse_args(argv)
    src = CIFARSource(args.dataset, data_dir=args.data_dir, seed=args.seed,
                      train_size=args.train_size, eval_size=args.eval_size)
    m = write_shards(args.out, src, shard_size=args.shard_size)
    tr, ev = m["splits"]["train"], m["splits"]["eval"]
    print(f"wrote {args.out}: {len(tr['shards'])} train shards "
          f"({tr['total']} examples) + {len(ev['shards'])} eval shards "
          f"({ev['total']} examples), shard_size={args.shard_size}")


if __name__ == "__main__":
    main()
