"""Host data pipeline: per-process sharded loading + device placement.

Mirrors the paper's DataLoader-with-DistributedSampler setup: each dp rank
sees a disjoint shard; weak-scaling mode subsets the dataset proportionally
to world size (the paper's §IV-A weak-scaling protocol).

Batches are **cursor-addressable**: ``batch_at(epoch, index)`` is a pure
function of ``(seed, epoch, index)``, so the TrainState data cursor
``(epoch, batch_index)`` saved by the elastic checkpoint layer names an
exact batch — a resumed run replays the identical stream from mid-epoch.
``Prefetcher`` overlaps next-batch synthesis + ``device_put`` with the
running compiled step (one-deep background prefetch, DeepSpeed
DataLoader-worker equivalent) while tracking the cursor for checkpointing.
"""
from __future__ import annotations

import math
import queue
import struct
import threading
import zlib
from typing import Iterator, Optional, Tuple

import jax
import numpy as np

from repro.data.synthetic import DatasetSpec, make_image_batch, \
    make_token_batch


def batch_seed(seed: int, epoch: int, i: int) -> int:
    """Stable 31-bit batch seed. Python's hash() is salted per process
    (PYTHONHASHSEED), so two launcher processes would derive *different*
    "identical" batches; crc32 over the packed tuple is process-invariant."""
    return zlib.crc32(struct.pack("<qqq", seed, epoch, i)) % (2 ** 31)


class DataPipeline:
    def __init__(self, *, kind: str, global_batch: int, seed: int = 0,
                 dataset: Optional[DatasetSpec] = None, vocab: int = 0,
                 seq_len: int = 0, resolution: Optional[int] = None,
                 weak_scaling_frac: float = 1.0, epoch_size: int = 0):
        """kind: 'image' | 'token'. weak_scaling_frac: fraction of the
        dataset used (paper: n_gpus x 10%)."""
        assert kind in ("image", "token")
        self.kind = kind
        self.global_batch = global_batch
        self.seed = seed
        self.dataset = dataset
        self.vocab = vocab
        self.seq_len = seq_len
        self.resolution = resolution
        n = epoch_size or (dataset.num_images if dataset else 50_000)
        self.epoch_size = int(n * weak_scaling_frac)

    @property
    def steps_per_epoch(self) -> int:
        return max(1, math.floor(self.epoch_size / self.global_batch))

    def batch_at(self, epoch: int, index: int) -> dict:
        """The batch at data cursor ``(epoch, index)`` — pure in
        ``(self.seed, epoch, index)``, the addressability contract the
        checkpoint resume path depends on."""
        if not 0 <= index < self.steps_per_epoch:
            raise IndexError(
                f"batch_index {index} out of range for epoch of "
                f"{self.steps_per_epoch} steps")
        seed = batch_seed(self.seed, epoch, index)
        if self.kind == "image":
            return make_image_batch(self.dataset, self.global_batch,
                                    seed=seed, resolution=self.resolution)
        return make_token_batch(self.vocab, self.global_batch,
                                self.seq_len, seed=seed)

    def batch_shapes(self) -> dict:
        """ShapeDtypeStructs of one batch, without synthesizing it (for
        deriving batch shardings before the first fetch)."""
        b = self.global_batch
        if self.kind == "image":
            res = self.resolution or self.dataset.resolution
            return {"images": jax.ShapeDtypeStruct((b, res, res, 3),
                                                   np.float32),
                    "labels": jax.ShapeDtypeStruct((b,), np.int32)}
        return {"tokens": jax.ShapeDtypeStruct((b, self.seq_len), np.int32)}

    def next_cursor(self, epoch: int, index: int) -> Tuple[int, int]:
        """Cursor of the batch after ``(epoch, index)`` — rolls the REAL
        epoch counter (epoch+1, not a reused step count, so batch seeds
        never repeat across epochs)."""
        index += 1
        if index >= self.steps_per_epoch:
            return epoch + 1, 0
        return epoch, index

    def batches(self, epoch: int = 0, start: int = 0) -> Iterator[dict]:
        for i in range(start, self.steps_per_epoch):
            yield self.batch_at(epoch, i)

    def prefetch(self, epoch: int = 0, index: int = 0, *, shardings=None,
                 depth: int = 1) -> "Prefetcher":
        """Background prefetcher starting at cursor ``(epoch, index)``
        (e.g. a restored TrainState's cursor), rolling epochs forever."""
        return Prefetcher(self, epoch, index, shardings=shardings,
                          depth=depth)

    def device_put(self, batch, shardings=None):
        if shardings is None:
            return jax.tree.map(jax.device_put, batch)
        return jax.tree.map(jax.device_put, batch, shardings)

    def local_shard(self, batch, rank: int, world: int):
        """The per-process slice a multi-host launcher would load (tested on
        one host; used by the launcher's process-sharded path)."""
        def slc(x):
            per = x.shape[0] // world
            return x[rank * per:(rank + 1) * per]
        return jax.tree.map(slc, batch)


class Prefetcher:
    """One-deep (configurable) background batch prefetcher.

    A daemon thread synthesizes the next batch and ``device_put``s it
    (against ``shardings`` when given, so arrival is already in the final
    dp layout) while the compiled step runs on the current one — the data
    path leaves the step critical path. ``next()`` yields
    ``(cursor, batch, next_cursor)``: ``cursor`` is the position of the
    yielded batch, ``next_cursor`` is what a checkpoint taken AFTER the
    step consuming this batch must record as the TrainState data cursor.

    Iterate forever (epochs roll automatically); ``close()`` (or the
    context manager) stops the thread. Synthesis errors re-raise on the
    consumer side.
    """

    def __init__(self, pipe: DataPipeline, epoch: int = 0, index: int = 0,
                 *, shardings=None, depth: int = 1):
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1: {depth}")
        self._pipe = pipe
        self._shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run, args=(int(epoch), int(index)),
            name="data-prefetch", daemon=True)
        self._thread.start()

    def _run(self, epoch: int, index: int):
        try:
            while not self._stop.is_set():
                batch = self._pipe.batch_at(epoch, index)
                batch = self._pipe.device_put(batch, self._shardings)
                item = ((epoch, index), batch,
                        self._pipe.next_cursor(epoch, index))
                while not self._stop.is_set():
                    try:
                        self._q.put(("ok", item), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                epoch, index = item[2]
        except BaseException as e:  # noqa: BLE001 — re-raised by consumer
            self._q.put(("error", e))

    def __iter__(self):
        return self

    def __next__(self):
        kind, item = self._q.get()
        if kind == "error":
            raise RuntimeError("data prefetch thread failed") from item
        return item

    def close(self):
        self._stop.set()
        # unblock a producer stuck in put() by draining
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
