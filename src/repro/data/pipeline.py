"""Host data pipeline: per-process sharded loading + device placement.

Mirrors the paper's DataLoader-with-DistributedSampler setup: each dp rank
sees a disjoint shard; weak-scaling mode subsets the dataset proportionally
to world size (the paper's §IV-A weak-scaling protocol).
"""
from __future__ import annotations

import math
import struct
import zlib
from typing import Iterator, Optional

import jax
import numpy as np

from repro.data.synthetic import DatasetSpec, make_image_batch, \
    make_token_batch


def batch_seed(seed: int, epoch: int, i: int) -> int:
    """Stable 31-bit batch seed. Python's hash() is salted per process
    (PYTHONHASHSEED), so two launcher processes would derive *different*
    "identical" batches; crc32 over the packed tuple is process-invariant."""
    return zlib.crc32(struct.pack("<qqq", seed, epoch, i)) % (2 ** 31)


class DataPipeline:
    def __init__(self, *, kind: str, global_batch: int, seed: int = 0,
                 dataset: Optional[DatasetSpec] = None, vocab: int = 0,
                 seq_len: int = 0, resolution: Optional[int] = None,
                 weak_scaling_frac: float = 1.0, epoch_size: int = 0):
        """kind: 'image' | 'token'. weak_scaling_frac: fraction of the
        dataset used (paper: n_gpus x 10%)."""
        assert kind in ("image", "token")
        self.kind = kind
        self.global_batch = global_batch
        self.seed = seed
        self.dataset = dataset
        self.vocab = vocab
        self.seq_len = seq_len
        self.resolution = resolution
        n = epoch_size or (dataset.num_images if dataset else 50_000)
        self.epoch_size = int(n * weak_scaling_frac)

    @property
    def steps_per_epoch(self) -> int:
        return max(1, math.floor(self.epoch_size / self.global_batch))

    def batches(self, epoch: int = 0) -> Iterator[dict]:
        for i in range(self.steps_per_epoch):
            seed = batch_seed(self.seed, epoch, i)
            if self.kind == "image":
                yield make_image_batch(self.dataset, self.global_batch,
                                       seed=seed, resolution=self.resolution)
            else:
                yield make_token_batch(self.vocab, self.global_batch,
                                       self.seq_len, seed=seed)

    def device_put(self, batch, shardings=None):
        if shardings is None:
            return jax.tree.map(jax.device_put, batch)
        return jax.tree.map(jax.device_put, batch, shardings)

    def local_shard(self, batch, rank: int, world: int):
        """The per-process slice a multi-host launcher would load (tested on
        one host; used by the launcher's process-sharded path)."""
        def slc(x):
            per = x.shape[0] // world
            return x[rank * per:(rank + 1) * per]
        return jax.tree.map(slc, batch)
