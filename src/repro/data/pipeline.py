"""Host data pipeline: per-process sharded loading + device placement.

Mirrors the paper's DataLoader-with-DistributedSampler setup: each dp rank
sees a disjoint shard; weak-scaling mode subsets the dataset proportionally
to world size (the paper's §IV-A weak-scaling protocol — the sampled index
POOL is restricted, not just the epoch length).

Batches are **cursor-addressable**: ``batch_at(epoch, index)`` is a pure
function of ``(seed, epoch, index)``, so the TrainState data cursor
``(epoch, batch_index)`` saved by the elastic checkpoint layer names an
exact batch — a resumed run replays the identical stream from mid-epoch.

``Prefetcher`` is the timm-PrefetchLoader equivalent: a two-stage
background pipeline (synthesis thread -> host queue -> transfer thread ->
device queue, each ``depth`` deep) that overlaps batch synthesis, the
host->device ``device_put``, and the running compiled step. Dataset
sources keep images **uint8 on the host** (4x fewer transferred bytes than
fp32); the jitted step finishes them on device (upsample + normalize —
``data/augment.device_preprocess``).
"""
from __future__ import annotations

import math
import queue
import struct
import threading
import warnings
import weakref
import zlib
from typing import Iterator, Optional, Tuple

import jax
import numpy as np

from repro.data.synthetic import DatasetSpec, make_image_batch, \
    make_token_batch
from repro.resilience import faults as _faults
from repro.resilience.backoff import BackoffPolicy

# prefetch-side retry of transient data-source errors: a flaky read
# (network blip, contended disk) resolves behind the prefetch overlap —
# the consumer only ever sees persistent failures
DEFAULT_DATA_BACKOFF = BackoffPolicy(max_attempts=3, base_delay=0.05,
                                     multiplier=2.0, max_delay=0.5,
                                     jitter=0.5)


def batch_seed(seed: int, epoch: int, i: int) -> int:
    """Stable 31-bit batch seed. Python's hash() is salted per process
    (PYTHONHASHSEED), so two launcher processes would derive *different*
    "identical" batches; crc32 over the packed tuple is process-invariant."""
    return zlib.crc32(struct.pack("<qqq", seed, epoch, i)) % (2 ** 31)


class DataPipeline:
    def __init__(self, *, kind: str, global_batch: int, seed: int = 0,
                 dataset: Optional[DatasetSpec] = None, vocab: int = 0,
                 seq_len: int = 0, resolution: Optional[int] = None,
                 weak_scaling_frac: float = 1.0, epoch_size: int = 0,
                 source=None):
        """kind: 'image' | 'token'. weak_scaling_frac: fraction of the
        dataset used (paper: n_gpus x 10%) — shortens the epoch AND
        restricts the index pool batches sample from (``sample_pool``),
        so each world size really trains on a proportional subset.
        ``source``: a :class:`repro.data.datasets.CIFARSource` or
        :class:`repro.data.streaming.ShardedSource` — image batches then
        come from its train split behind the same ``batch_at`` cursor
        contract (uint8, native resolution); without it, images are
        spec-shaped pre-normalized fp32 synthetic tensors."""
        assert kind in ("image", "token")
        if source is not None and kind != "image":
            raise ValueError("dataset sources only back the image kind")
        if not 0.0 < weak_scaling_frac <= 1.0:
            raise ValueError(
                f"weak_scaling_frac must be in (0, 1]: {weak_scaling_frac}")
        self.kind = kind
        self.global_batch = global_batch
        self.seed = seed
        self.dataset = source.spec if source is not None else dataset
        self.source = source
        self.vocab = vocab
        self.seq_len = seq_len
        self.resolution = source.resolution if source is not None \
            else resolution
        n = epoch_size or (source.train_size if source is not None
                           else self.dataset.num_images
                           if self.dataset else 50_000)
        self.epoch_size = int(n * weak_scaling_frac)
        # §IV-A weak scaling: restrict the SAMPLED pool, not just the
        # epoch length (regression: batches used to keep sampling the
        # full split, silently breaking the proportional-subset protocol)
        self.sample_pool = None
        if source is not None and weak_scaling_frac < 1.0:
            self.sample_pool = max(1, int(source.train_size
                                          * weak_scaling_frac))

    @property
    def steps_per_epoch(self) -> int:
        return max(1, math.floor(self.epoch_size / self.global_batch))

    def batch_at(self, epoch: int, index: int) -> dict:
        """The batch at data cursor ``(epoch, index)`` — pure in
        ``(self.seed, epoch, index)``, the addressability contract the
        checkpoint resume path depends on."""
        if not 0 <= index < self.steps_per_epoch:
            raise IndexError(
                f"batch_index {index} out of range for epoch of "
                f"{self.steps_per_epoch} steps")
        _faults.check("data", index)    # chaos harness (no-op in prod)
        seed = batch_seed(self.seed, epoch, index)
        if self.kind == "image":
            if self.source is not None:
                return self.source.train_batch(self.global_batch, seed=seed,
                                               pool=self.sample_pool)
            return make_image_batch(self.dataset, self.global_batch,
                                    seed=seed, resolution=self.resolution)
        return make_token_batch(self.vocab, self.global_batch,
                                self.seq_len, seed=seed)

    def batch_shapes(self) -> dict:
        """ShapeDtypeStructs of one batch, without synthesizing it (for
        deriving batch shardings before the first fetch). Dataset sources
        ship uint8 at the NATIVE grid (the on-device preprocess upsamples
        to the model resolution); the legacy synthetic stream stays
        pre-normalized fp32 at the model resolution."""
        b = self.global_batch
        if self.kind == "image":
            if self.source is not None:
                r = self.source.native_resolution
                return {"images": jax.ShapeDtypeStruct((b, r, r, 3),
                                                       np.uint8),
                        "labels": jax.ShapeDtypeStruct((b,), np.int32)}
            res = self.resolution or self.dataset.resolution
            return {"images": jax.ShapeDtypeStruct((b, res, res, 3),
                                                   np.float32),
                    "labels": jax.ShapeDtypeStruct((b,), np.int32)}
        return {"tokens": jax.ShapeDtypeStruct((b, self.seq_len), np.int32)}

    def next_cursor(self, epoch: int, index: int) -> Tuple[int, int]:
        """Cursor of the batch after ``(epoch, index)`` — rolls the REAL
        epoch counter (epoch+1, not a reused step count, so batch seeds
        never repeat across epochs)."""
        index += 1
        if index >= self.steps_per_epoch:
            return epoch + 1, 0
        return epoch, index

    def batches(self, epoch: int = 0, start: int = 0) -> Iterator[dict]:
        for i in range(start, self.steps_per_epoch):
            yield self.batch_at(epoch, i)

    def prefetch(self, epoch: int = 0, index: int = 0, *, shardings=None,
                 depth: int = 2,
                 retry: Optional[BackoffPolicy] = DEFAULT_DATA_BACKOFF
                 ) -> "Prefetcher":
        """Background prefetcher starting at cursor ``(epoch, index)``
        (e.g. a restored TrainState's cursor), rolling epochs forever.
        ``depth`` bounds the batches in flight at EACH stage (synthesis
        and device transfer run in separate threads — see Prefetcher).
        Transient source errors are retried per ``retry`` before anything
        reaches the consumer (None = no retry)."""
        return Prefetcher(self, epoch, index, shardings=shardings,
                          depth=depth, retry=retry)

    def device_put(self, batch, shardings=None):
        if shardings is None:
            return jax.tree.map(jax.device_put, batch)
        return jax.tree.map(jax.device_put, batch, shardings)

    def local_shard(self, batch, rank: int, world: int):
        """The per-process slice a multi-host launcher would load (tested on
        one host; used by the launcher's process-sharded path). A batch
        that does not divide evenly across the world is an error — the
        old silent truncation trained on a shorter batch than requested."""
        def slc(x):
            if x.shape[0] % world:
                raise ValueError(
                    f"global batch dimension {x.shape[0]} not divisible "
                    f"by world size {world}; the remainder would be "
                    f"silently dropped")
            per = x.shape[0] // world
            return x[rank * per:(rank + 1) * per]
        return jax.tree.map(slc, batch)


class Prefetcher:
    """N-deep background batch prefetcher, two pipelined stages.

    Stage 1 (``data-synth`` thread) synthesizes/loads host batches and
    rolls the cursor; stage 2 (``data-transfer`` thread) ``device_put``s
    them (against ``shardings`` when given, so arrival is already in the
    final dp layout). Each stage is decoupled by a ``depth``-deep queue,
    so with depth N: the compiled step consumes batch k while batch k+1
    transfers and batches up to k+1+N synthesize — synthesis and transfer
    no longer serialize per batch (the double-buffered timm-PrefetchLoader
    overlap). ``next()`` yields ``(cursor, batch, next_cursor)``:
    ``cursor`` is the position of the yielded batch, ``next_cursor`` is
    what a checkpoint taken AFTER the step consuming this batch must
    record as the TrainState data cursor.

    Iterate forever (epochs roll automatically); ``close()`` (or the
    context manager) stops both threads. TRANSIENT synthesis errors
    (``OSError``, incl. the fault harness's ``TransientError``) are
    retried in the synthesis stage with bounded jittered backoff — the
    retry sleeps are stop-aware, so ``close()`` is never blocked by a
    retry in progress; only persistent errors (or exhausted retries)
    re-raise on the consumer side.

    Lifecycle guarantees (regression-tested in test_data_pipeline.py):
    every queue interaction on the producer side is **stop-aware** — in
    particular the error hand-off, which previously used a blocking
    ``put`` and stranded the thread forever when the producer raised
    while the queue was full and the consumer had stopped consuming.
    ``close()`` is idempotent, always joins both threads, and — instead
    of silently leaking a producer that outlives the join timeout — warns
    with the pending cursor so a hung data source is diagnosable.
    ``__next__`` after ``close()`` raises ``StopIteration`` instead of
    blocking on the drained queue; dropping the last reference without
    ``close()`` still reclaims the threads via ``__del__`` (belt-and-
    braces — the context manager is the intended API).
    """

    JOIN_TIMEOUT = 5.0

    def __init__(self, pipe: DataPipeline, epoch: int = 0, index: int = 0,
                 *, shardings=None, depth: int = 2,
                 retry: Optional[BackoffPolicy] = DEFAULT_DATA_BACKOFF):
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1: {depth}")
        self._pipe = pipe
        self._shardings = shardings
        self.depth = depth
        self._host_q: queue.Queue = queue.Queue(maxsize=depth)
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        # cursor of the batch the synthesis stage is currently producing
        # (mutated in place by the synth thread; read by close() for the
        # leak diagnostic) — a plain list so the thread needs no strong
        # reference to self
        self._cursor_box = [int(epoch), int(index)]
        # the thread targets must NOT hold a strong ref to self: the
        # consumer dropping its last reference is what lets __del__ stop
        # the producers (a bound-method target would keep the Prefetcher
        # alive from the thread's own frame, making the leak
        # unreclaimable)
        ref = weakref.ref(self)
        self._synth_thread = threading.Thread(
            target=_synth_loop,
            args=(ref, pipe, self._host_q, self._stop, int(epoch),
                  int(index), retry, self._cursor_box),
            name="data-synth", daemon=True)
        self._xfer_thread = threading.Thread(
            target=_xfer_loop,
            args=(ref, pipe, self._host_q, self._q, self._stop, shardings),
            name="data-transfer", daemon=True)
        self._threads = (self._synth_thread, self._xfer_thread)
        for t in self._threads:
            t.start()

    def __iter__(self):
        return self

    def __next__(self):
        while True:
            try:
                kind, item = self._q.get(timeout=0.1)
                break
            except queue.Empty:
                if self._stop.is_set():
                    raise StopIteration("prefetcher closed")
                if not any(t.is_alive() for t in self._threads):
                    # producers exited: already-delivered error consumed,
                    # or they died before enqueueing — surface either way
                    if self._error is not None:
                        raise RuntimeError(
                            "data prefetch thread failed") from self._error
                    raise StopIteration("prefetch thread exited")
        if kind == "error":
            raise RuntimeError("data prefetch thread failed") from item
        return item

    def _drain(self):
        for q in (self._host_q, self._q):
            try:
                while True:
                    q.get_nowait()
            except queue.Empty:
                pass

    def close(self):
        """Idempotent: stop both stages, unblock any pending put by
        draining, and join the threads. A thread still alive after the
        join timeout is a HUNG producer (wedged data source / device
        transfer) — warn with the pending cursor instead of leaking it
        silently."""
        self._stop.set()
        self._drain()
        for t in self._threads:
            t.join(timeout=self.JOIN_TIMEOUT)
        self._drain()       # anything put between drain and thread exit
        hung = [t.name for t in self._threads if t.is_alive()]
        if hung:
            warnings.warn(
                f"Prefetcher.close(): {', '.join(hung)} still alive "
                f"{self.JOIN_TIMEOUT:.0f}s after the join — the thread is "
                f"leaked (pending cursor (epoch {self._cursor_box[0]}, "
                f"batch {self._cursor_box[1]})); the data source or "
                f"device transfer is likely hung there",
                RuntimeWarning, stacklevel=2)

    def __del__(self):
        try:
            if not self._stop.is_set():
                self.close()
        except Exception:   # noqa: BLE001 — interpreter-shutdown tolerant
            pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def _stop_aware_put(q: queue.Queue, stop: threading.Event, msg) -> bool:
    """Put that gives up (drops the message) once the consumer has
    closed, instead of blocking forever on a full queue."""
    while not stop.is_set():
        try:
            q.put(msg, timeout=0.1)
            return True
        except queue.Full:
            continue
    return False


def _deliver_error(ref, q, stop, exc):
    """Record the error on the owner (weakly — see _synth_loop) and hand
    it down the pipeline with a stop-aware put."""
    owner = ref()
    if owner is not None and owner._error is None:
        owner._error = exc
        del owner           # drop the strong ref before parking in put
    _stop_aware_put(q, stop, ("error", exc))


def _synth_loop(ref, pipe: DataPipeline, host_q: queue.Queue,
                stop: threading.Event, epoch: int, index: int,
                retry: Optional[BackoffPolicy], cursor_box):
    """Stage-1 body (module-level — see Prefetcher.__init__ on why it
    only weakly references its owner): synthesize host batches, roll the
    cursor, hand them to the transfer stage. ``retry`` bounds the
    transient-error retries of the source fetch; the backoff sleeps wait
    on the stop event, so a close() during a retry returns immediately."""
    def fetch(e, i):
        if retry is None:
            return pipe.batch_at(e, i)
        return retry.retry(
            lambda: pipe.batch_at(e, i), retryable=(OSError,),
            sleep=lambda d: stop.wait(d),
            on_retry=lambda a, d, exc: print(
                f"[data] transient source error at ({e}, {i}) attempt "
                f"{a + 1} ({exc}); retrying in {d:.2f}s", flush=True))

    try:
        while not stop.is_set():
            cursor_box[0], cursor_box[1] = epoch, index
            batch = fetch(epoch, index)
            item = ((epoch, index), batch, pipe.next_cursor(epoch, index))
            if not _stop_aware_put(host_q, stop, ("ok", item)):
                return
            epoch, index = item[2]
    except BaseException as e:  # noqa: BLE001 — re-raised by consumer
        _deliver_error(ref, host_q, stop, e)


def _xfer_loop(ref, pipe: DataPipeline, host_q: queue.Queue,
               dev_q: queue.Queue, stop: threading.Event, shardings):
    """Stage-2 body: move host batches onto the devices. Runs in its own
    thread so the (possibly sharded) ``device_put`` of batch k+1 overlaps
    BOTH the running step on batch k and the synthesis of k+2 — the
    double-buffered transfer the one-thread prefetcher couldn't give."""
    try:
        while not stop.is_set():
            try:
                kind, item = host_q.get(timeout=0.1)
            except queue.Empty:
                continue
            if kind == "error":
                # forward the synthesis failure and shut the stage down
                _stop_aware_put(dev_q, stop, ("error", item))
                return
            cursor, batch, nxt = item
            batch = pipe.device_put(batch, shardings)
            if not _stop_aware_put(dev_q, stop, ("ok", (cursor, batch,
                                                        nxt))):
                return
    except BaseException as e:  # noqa: BLE001 — re-raised by consumer
        _deliver_error(ref, dev_q, stop, e)
