"""On-device, jit-able image preprocessing + augmentation: uint8 upsample
and normalize, random crop + horizontal flip + Mixup/CutMix with soft
labels.

The standard ViT-on-CIFAR regularization recipe (pytorch-image-models /
"Scaling Vision Transformers" conventions), implemented as a pure function
of a PRNG key so it runs *inside* the jitted train step:

    batch = augment_batch(rng, batch, acfg)

The engine threads the key from the TrainState convention —
``fold_in(state.rng, state.step)`` split per microbatch — so the
augmentation stream is a pure function of ``(base rng, step, microbatch)``
and a resumed run replays the exact stream of the run it interrupted (the
resume-parity contract extends to augmented training).

Everything is branchless (``jnp.where`` over both candidates, no
``lax.cond``) so one compiled step serves every draw. Mixup/CutMix emit
**soft labels** ``(B, num_classes)``: each row is the convex combination
``lam * onehot(y) + (1-lam) * onehot(y[perm])`` (rows sum to 1 and lie in
the convex hull of the pair — property-tested). With both alphas 0 the
labels pass through hard, and crop/flip never touch labels at all.

Data arrives **uint8 at the native grid** (the timm-PrefetchLoader host
path, ``data/datasets.py``): :func:`device_preprocess` / the uint8 branch
of :func:`augment_batch` finish the batch on device — nearest-neighbor
upsample to the model resolution, then the fused cast-and-normalize
``u8 * (1/(255*std)) - mean/std``. The geometric augmentations compose on
the uint8-ranged images (pad/slice/flip are dtype-agnostic and 4x cheaper
at 8 bits); normalization happens after them and before Mixup/CutMix,
which needs linear fp32 pixel space.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AugmentConfig:
    """Static (hashable) augmentation recipe — jit-safe as a closure
    constant; one compiled step per recipe."""
    num_classes: int
    crop_pad: int = 4           # zero-pad each side, then random crop back
    flip: bool = True           # horizontal flip with p=0.5
    mixup_alpha: float = 0.2    # Beta(a, a) mixing weight; 0 disables
    cutmix_alpha: float = 1.0   # Beta(a, a) box area; 0 disables
    mix_prob: float = 0.5       # probability a batch is mixed at all
    switch_prob: float = 0.5    # P(cutmix | mixing) when both enabled

    @property
    def mixing(self) -> bool:
        return self.mixup_alpha > 0.0 or self.cutmix_alpha > 0.0

    def validate(self):
        if self.num_classes <= 0:
            raise ValueError(
                f"AugmentConfig.num_classes must be positive: "
                f"{self.num_classes} (soft labels need the class count)")
        if self.crop_pad < 0:
            raise ValueError(f"crop_pad must be >= 0: {self.crop_pad}")
        return self


# ---------------------------------------------------------------------------
# device-side preprocessing (the other half of the uint8 host data path)
# ---------------------------------------------------------------------------

def upsample(images, resolution: int):
    """Nearest-neighbor upsample to the model resolution, on device and
    dtype-preserving — uint8 images stay uint8 until :func:`normalize`,
    so the big model-resolution array is only ever fp32 AFTER the cheap
    8-bit repeat."""
    native = images.shape[1]
    if resolution == native:
        return images
    if resolution % native:
        raise ValueError(
            f"model resolution {resolution} not an integer multiple of "
            f"the native {native}px grid")
    k = resolution // native
    return jnp.repeat(jnp.repeat(images, k, axis=1), k, axis=2)


def normalize(images, preproc):
    """Fused uint8 -> normalized fp32: one multiply-add per pixel,
    ``x * 1/(255*std) - mean/std`` — algebraically identical to the host
    reference ``(x/255 - mean) / std`` (datasets.normalize_images), pinned
    to fp32 tolerance by the parity test."""
    scale = jnp.asarray([1.0 / (255.0 * s) for s in preproc.std],
                        jnp.float32)
    bias = jnp.asarray([-m / s for m, s in zip(preproc.mean, preproc.std)],
                       jnp.float32)
    return images.astype(jnp.float32) * scale + bias


def device_preprocess(batch: dict, preproc, resolution: int) -> dict:
    """Finish a host uint8 batch on device: upsample to the model
    resolution, then cast-and-normalize. A no-op for float batches (the
    legacy synthetic stream ships pre-normalized fp32); a uint8 batch
    without a ``preproc`` is a wiring error and raises at trace time."""
    img = batch.get("images")
    if img is None or img.dtype != jnp.uint8:
        return batch
    if preproc is None:
        raise ValueError(
            "got a uint8 image batch but no normalization statistics — "
            "pass preproc=source.preproc to DistributedEngine (or "
            "device_preprocess) so the on-device normalize knows the "
            "dataset's mean/std")
    out = dict(batch)
    out["images"] = normalize(upsample(img, resolution), preproc)
    return out


def random_crop(rng, images, pad: int):
    """Pad-and-crop with a per-sample offset (the CIFAR-standard
    RandomCrop(32, padding=4)); label-invariant by construction."""
    if pad == 0:
        return images
    b, h, w, c = images.shape
    padded = jnp.pad(images, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    off = jax.random.randint(rng, (b, 2), 0, 2 * pad + 1)

    def crop_one(img, o):
        return jax.lax.dynamic_slice(img, (o[0], o[1], 0), (h, w, c))

    return jax.vmap(crop_one)(padded, off)


def random_flip(rng, images):
    """Per-sample horizontal flip with p=0.5."""
    flip = jax.random.bernoulli(rng, 0.5, (images.shape[0],))
    return jnp.where(flip[:, None, None, None], images[:, :, ::-1], images)


def _cutmix_mask(rng, h: int, w: int, lam):
    """Random box covering fraction ``1 - lam`` of the image; returns
    (mask (h, w) with 1 inside the box, realized box fraction)."""
    kx, ky = jax.random.split(rng)
    cut = jnp.sqrt(1.0 - lam)
    bh = jnp.round(cut * h).astype(jnp.int32)
    bw = jnp.round(cut * w).astype(jnp.int32)
    cy = jax.random.randint(ky, (), 0, h)
    cx = jax.random.randint(kx, (), 0, w)
    y0 = jnp.clip(cy - bh // 2, 0, h)
    y1 = jnp.clip(cy + (bh + 1) // 2, 0, h)
    x0 = jnp.clip(cx - bw // 2, 0, w)
    x1 = jnp.clip(cx + (bw + 1) // 2, 0, w)
    rows = jnp.arange(h)[:, None]
    cols = jnp.arange(w)[None, :]
    mask = ((rows >= y0) & (rows < y1) & (cols >= x0) & (cols < x1))
    frac = (y1 - y0) * (x1 - x0) / (h * w)
    return mask.astype(jnp.float32), frac.astype(jnp.float32)


def mix_batch(rng, images, onehot, acfg: AugmentConfig):
    """Batch-level Mixup OR CutMix (timm convention: one draw per batch).

    Returns (mixed images, soft labels). The soft labels use the
    *realized* mixing fraction (CutMix clamps the box at image borders, so
    the pixel fraction — not the sampled lam — is what the labels see)."""
    k_lam_mix, k_lam_cut, k_apply, k_switch, k_perm, k_box = \
        jax.random.split(rng, 6)
    b, h, w, _ = images.shape
    perm = jax.random.permutation(k_perm, b)
    im2, oh2 = images[perm], onehot[perm]

    use_cutmix = jnp.logical_and(
        jax.random.bernoulli(k_switch, acfg.switch_prob),
        acfg.cutmix_alpha > 0.0) if acfg.mixup_alpha > 0.0 \
        else jnp.asarray(acfg.cutmix_alpha > 0.0)

    lam_mix = jax.random.beta(
        k_lam_mix, acfg.mixup_alpha or 1.0, acfg.mixup_alpha or 1.0)
    box, box_frac = _cutmix_mask(
        k_box, h, w, jax.random.beta(
            k_lam_cut, acfg.cutmix_alpha or 1.0, acfg.cutmix_alpha or 1.0))

    mixed_up = lam_mix * images + (1.0 - lam_mix) * im2
    cut = images * (1.0 - box)[None, :, :, None] + \
        im2 * box[None, :, :, None]
    lam = jnp.where(use_cutmix, 1.0 - box_frac, lam_mix)
    out_images = jnp.where(use_cutmix, cut, mixed_up)
    out_labels = lam * onehot + (1.0 - lam) * oh2

    apply = jax.random.bernoulli(k_apply, acfg.mix_prob)
    return (jnp.where(apply, out_images, images),
            jnp.where(apply, out_labels, onehot))


def augment_batch(rng, batch: dict, acfg: AugmentConfig, *,
                  preproc=None, resolution: int = 0) -> dict:
    """Full train-time augmentation of one (micro)batch.

    In: ``{"images": (B,H,W,3), "labels": (B,) int}``. Out: images at the
    model resolution, normalized fp32 when the input was uint8; labels
    become soft ``(B, num_classes)`` float32 when mixing is enabled, and
    stay hard ints otherwise (geometric augs are label-invariant). Pure in
    ``rng`` — the determinism contract.

    uint8 inputs (the streaming host path) compose as: on-device upsample
    (8-bit) -> crop/flip on the uint8-ranged images -> fused
    cast-and-normalize -> Mixup/CutMix in fp32. ``preproc`` is required
    then; float inputs take the legacy path (same rng split layout, so
    augmentation streams are unchanged)."""
    k_crop, k_flip, k_mix = jax.random.split(rng, 3)
    images = batch["images"]
    was_uint8 = images.dtype == jnp.uint8
    if was_uint8:
        if preproc is None:
            raise ValueError(
                "augment_batch on a uint8 batch needs preproc= (the "
                "dataset's mean/std) for the post-crop normalize")
        images = upsample(images, resolution or images.shape[1])
    images = random_crop(k_crop, images, acfg.crop_pad)
    if acfg.flip:
        images = random_flip(k_flip, images)
    if was_uint8:
        images = normalize(images, preproc)
    out = dict(batch)
    out["images"] = images
    if acfg.mixing:
        onehot = jax.nn.one_hot(batch["labels"], acfg.num_classes,
                                dtype=jnp.float32)
        images, soft = mix_batch(k_mix, images, onehot, acfg)
        out["images"] = images
        out["labels"] = soft
    return out
