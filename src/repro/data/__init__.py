from repro.data.synthetic import (  # noqa: F401
    DATASETS,
    DatasetSpec,
    make_image_batch,
    make_token_batch,
)
from repro.data.pipeline import DataPipeline, Prefetcher  # noqa: F401
