from repro.data.synthetic import (  # noqa: F401
    DATASETS,
    DatasetSpec,
    make_image_batch,
    make_token_batch,
)
from repro.data.pipeline import DataPipeline, Prefetcher  # noqa: F401
from repro.data.datasets import CIFARSource, make_source  # noqa: F401
from repro.data.augment import AugmentConfig, augment_batch  # noqa: F401
