from repro.data.synthetic import (  # noqa: F401
    DATASETS,
    DatasetSpec,
    make_image_batch,
    make_token_batch,
)
from repro.data.pipeline import DataPipeline, Prefetcher  # noqa: F401
from repro.data.datasets import (  # noqa: F401
    CIFARSource,
    Preproc,
    make_source,
    normalize_images,
)
from repro.data.augment import (  # noqa: F401
    AugmentConfig,
    augment_batch,
    device_preprocess,
)


def __getattr__(name):
    # streaming is lazy so `python -m repro.data.streaming` (the shard
    # writer CLI) doesn't trip runpy's found-in-sys.modules warning
    if name in ("ShardedSource", "write_shards"):
        from repro.data import streaming
        return getattr(streaming, name)
    raise AttributeError(name)
