"""WKV6 (RWKV6 recurrence) Pallas TPU kernels — chunked matmul form,
forward + backward, custom VJP.

TPU adaptation (DESIGN.md §6): the reference CUDA wkv6 kernel serializes one
thread per channel over the whole sequence; here each (batch, head) runs a
sequential grid axis over chunks, carrying the (P, P) state in VMEM scratch,
while the intra-chunk work is two MXU matmuls + one VPU pairwise-decay
contraction. The pairwise decay exp(L_{t-1} - L_j) <= 1 for j < t, so the
kernel is fp32-overflow-safe under arbitrarily strong decay (unlike the
factored r·e^L / k·e^-L formulation).

Backward pass (the training hot path)
-------------------------------------
``wkv6_chunked_kernel`` is a ``jax.custom_vjp`` built on the shared
``kernels.vjp`` harness — training through RWKV6 never differentiates the
interpret/Mosaic forward body. The VJP forward additionally emits the
*entering* state of every chunk (fp32, (B,H,NC,P,P)) as a residual
(non-differentiated forwards — eval, decode — take a residual-free primal
variant that skips this output entirely); the backward kernel
walks the chunk axis **in reverse** (grid index maps flip ci -> NC-1-ci),
carrying the state cotangent ``G_c = dL/dS_c`` in fp32 VMEM scratch via the
reverse recurrence

    G_{c-1} = rdec_cᵀ · dO_c  +  diag(e^{L_end,c}) G_c

and reconstituting the intra-chunk pairwise tensors (bounded, clip-free for
the live strictly-causal triangle) to produce dr/dk/dv/dwlog per chunk plus
the du bonus reduction (accumulated per (B,H) in scratch, summed over batch
outside) and dS0 at the final (= first) chunk. All accumulation is fp32;
gradients are cast to the primal dtypes at the flush (harness policy).

Layout: r/k/v/wlog rearranged to (B, H, NC, CS, P) internally.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import vjp


class _Spec(NamedTuple):
    """Static kernel configuration (hashable: custom_vjp nondiff arg)."""
    chunk: int
    interpret: bool


# ---------------------------------------------------------------------------
# forward kernel (chunked state recurrence; emits entering states residual)
# ---------------------------------------------------------------------------

def _fwd_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                o_ref, s_out_ref, *refs, chunk, num_chunks, with_states):
    # primal-only forwards (eval/decode) skip the states residual output —
    # XLA can't dead-code an output out of a multi-output pallas_call
    if with_states:
        states_ref, state_scr = refs
    else:
        (state_scr,) = refs
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    if with_states:
        # entering state of this chunk — the backward's residual
        states_ref[0, 0, 0] = state_scr[...]

    r = r_ref[0, 0, 0].astype(jnp.float32)         # (cs, P)
    k = k_ref[0, 0, 0].astype(jnp.float32)
    v = v_ref[0, 0, 0].astype(jnp.float32)
    w = w_ref[0, 0, 0].astype(jnp.float32)         # log-decay, <= 0
    u = u_ref[0].astype(jnp.float32)               # (P,)

    L = jnp.cumsum(w, axis=0)                      # inclusive
    lprev = L - w
    state = state_scr[...]

    # carried-state contribution
    o = jax.lax.dot(r * jnp.exp(lprev), state,
                    preferred_element_type=jnp.float32)

    # intra-chunk strictly-causal pairwise term (bounded decay <= 1)
    pair = jnp.exp(jnp.minimum(lprev[:, None, :] - L[None, :, :], 0.0))
    att = jnp.sum(r[:, None, :] * pair * k[None, :, :], axis=-1)  # (cs, cs)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(j_idx < t_idx, att, 0.0)
    o = o + jax.lax.dot(att, v, preferred_element_type=jnp.float32)

    # diagonal bonus
    diag = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True)
    o = o + diag * v

    # state update: S <- diag(e^{L_end}) S + (k ⊙ e^{L_end - L})^T v
    l_end = L[-1:, :]                              # (1, P)
    k_adv = k * jnp.exp(l_end - L)
    state_scr[...] = (jnp.exp(l_end).T * state
                      + jax.lax.dot(k_adv.T, v,
                                    preferred_element_type=jnp.float32))

    o_ref[0, 0, 0] = o.astype(o_ref.dtype)

    @pl.when(ci == num_chunks - 1)
    def _final():
        s_out_ref[0, 0] = state_scr[...].astype(s_out_ref.dtype)


def _to_chunked(x, b, nc, cs, h, p):
    return x.reshape(b, nc, cs, h, p).transpose(0, 3, 1, 2, 4)


def _from_chunked(x, b, s, h, p):
    return x.transpose(0, 2, 3, 1, 4).reshape(b, s, h, p)


def _forward(spec, r, k, v, wlog, u, s0, *, with_states):
    b, s, h, p = r.shape
    cs = spec.chunk
    nc = s // cs
    assert nc * cs == s, (s, cs)

    rc, kc, vc, wc = (_to_chunked(x, b, nc, cs, h, p)
                      for x in (r, k, v, wlog))

    def rkvw_map(bb, hh, ci):
        return (bb, hh, ci, 0, 0)

    def u_map(bb, hh, ci):
        return (hh, 0)

    def s0_map(bb, hh, ci):
        return (bb, hh, 0, 0)

    out_specs = [
        pl.BlockSpec((1, 1, 1, cs, p), rkvw_map),
        pl.BlockSpec((1, 1, p, p), s0_map),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, h, nc, cs, p), jnp.float32),
        jax.ShapeDtypeStruct((b, h, p, p), jnp.float32),
    ]
    if with_states:
        out_specs.append(pl.BlockSpec((1, 1, 1, p, p),
                                      lambda bb, hh, ci: (bb, hh, ci, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((b, h, nc, p, p), jnp.float32))

    outs = pl.pallas_call(
        functools.partial(_fwd_kernel, chunk=cs, num_chunks=nc,
                          with_states=with_states),
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, cs, p), rkvw_map),
            pl.BlockSpec((1, 1, 1, cs, p), rkvw_map),
            pl.BlockSpec((1, 1, 1, cs, p), rkvw_map),
            pl.BlockSpec((1, 1, 1, cs, p), rkvw_map),
            pl.BlockSpec((1, p), u_map),
            pl.BlockSpec((1, 1, p, p), s0_map),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((p, p), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=spec.interpret,
    )(rc, kc, vc, wc, u, s0)

    o = _from_chunked(outs[0], b, s, h, p)
    s_end = outs[1]
    states = outs[2] if with_states else None
    return o, s_end, states


# ---------------------------------------------------------------------------
# backward kernel (reverse-chunk state-gradient recurrence)
# ---------------------------------------------------------------------------

def _bwd_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s_ref, do_ref, dsend_ref,
                dr_ref, dk_ref, dv_ref, dw_ref, ds0_ref, du_ref,
                g_scr, du_scr, *, chunk, num_chunks):
    ci = pl.program_id(2)              # 0..nc-1, index maps reverse it

    @pl.when(ci == 0)
    def _init():
        g_scr[...] = dsend_ref[0, 0].astype(jnp.float32)
        du_scr[...] = jnp.zeros_like(du_scr)

    r = r_ref[0, 0, 0].astype(jnp.float32)         # (cs, P)
    k = k_ref[0, 0, 0].astype(jnp.float32)
    v = v_ref[0, 0, 0].astype(jnp.float32)
    w = w_ref[0, 0, 0].astype(jnp.float32)         # log-decay, <= 0
    u = u_ref[0].astype(jnp.float32)               # (P,)
    state = s_ref[0, 0, 0]                         # entering state (P, P) f32
    do = do_ref[0, 0, 0].astype(jnp.float32)       # (cs, P)
    g = g_scr[...]                                 # dL/dS_out of this chunk

    L = jnp.cumsum(w, axis=0)
    lprev = L - w
    l_end = L[-1:, :]                              # (1, P)
    e_lprev = jnp.exp(lprev)
    e_adv = jnp.exp(l_end - L)                     # kadv decay, <= 1
    rdec = r * e_lprev
    kadv = k * e_adv

    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = (j_idx < t_idx)[:, :, None]              # strictly causal (t, j, 1)
    # live-triangle pairwise decay: lprev_t - L_j <= 0 for j < t, so no
    # clip is needed once tri masks the upper triangle (and the masked
    # entries' exp can't overflow: min() bounds them at 1)
    pair = jnp.where(tri, jnp.exp(jnp.minimum(
        lprev[:, None, :] - L[None, :, :], 0.0)), 0.0)  # (cs, cs, P)

    # --- intra-chunk attention adjoints ---
    dA = jnp.where(tri[..., 0], jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32), 0.0)  # (t, j)
    T1 = dA[:, :, None] * pair                     # (t, j, P)
    dr_att = jnp.sum(T1 * k[None, :, :], axis=1)   # (cs, P)
    dk_att = jnp.sum(T1 * r[:, None, :], axis=0)   # (cs, P)
    E = T1 * r[:, None, :] * k[None, :, :]         # dA ∘ ∂A/∂(lprev-L)
    dlprev_pair = jnp.sum(E, axis=1)               # (cs, P) — per t
    dL_pair = -jnp.sum(E, axis=0)                  # (cs, P) — per j

    # --- carried-state contribution o += rdec · S ---
    drdec = jax.lax.dot_general(do, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # do·Sᵀ
    # --- state cotangent entering this chunk ---
    #   S_out = diag(e^{L_end}) S + kadvᵀ v  and  o_t += rdec_t · S
    ds_in = (jax.lax.dot_general(rdec, do, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
             + jnp.exp(l_end).T * g)               # (P, P)

    # --- dv: A' v term + state-update term + diagonal bonus ---
    att = jnp.sum(r[:, None, :] * pair * k[None, :, :], axis=-1)  # (t, j)
    diag = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True)    # (cs, 1)
    dov = jnp.sum(do * v, axis=-1, keepdims=True)                 # (cs, 1)
    dv = (jax.lax.dot_general(att, do, (((0,), (0,)), ((), ())),  # Aᵀ·dO
                              preferred_element_type=jnp.float32)
          + jax.lax.dot(kadv, g, preferred_element_type=jnp.float32)
          + diag * do)

    # --- dk / dr ---
    dkadv = jax.lax.dot_general(v, g, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # v·Gᵀ
    dk = dk_att + dkadv * e_adv + u[None, :] * r * dov
    dr = dr_att + drdec * e_lprev + u[None, :] * k * dov
    du_scr[...] += jnp.sum(r * k * dov, axis=0, keepdims=True)

    # --- decay gradients via the cumsum adjoint ---
    # w -> L = cumsum(w) -> {lprev = L - w, l_end = L[-1]}
    dlprev = drdec * rdec + dlprev_pair
    dl_end = (jnp.sum(dkadv * kadv, axis=0, keepdims=True)
              + jnp.exp(l_end) * jnp.sum(state * g, axis=1)[None, :])
    last_row = (jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
                == chunk - 1)
    dL_tot = (dL_pair - dkadv * kadv + dlprev
              + jnp.where(last_row, dl_end, 0.0))
    # reverse cumsum: dw_t = Σ_{j>=t} dL_j, minus the direct -w term of lprev
    rev = jnp.sum(dL_tot, axis=0, keepdims=True) \
        - jnp.cumsum(dL_tot, axis=0) + dL_tot
    dw = rev - dlprev

    dr_ref[0, 0, 0] = dr.astype(dr_ref.dtype)
    dk_ref[0, 0, 0] = dk.astype(dk_ref.dtype)
    dv_ref[0, 0, 0] = dv.astype(dv_ref.dtype)
    dw_ref[0, 0, 0] = dw.astype(dw_ref.dtype)
    g_scr[...] = ds_in

    @pl.when(ci == num_chunks - 1)
    def _final():
        ds0_ref[0, 0] = g_scr[...].astype(ds0_ref.dtype)
        du_ref[0, 0] = du_scr[0].astype(du_ref.dtype)


def _backward(spec, r, k, v, wlog, u, s0, states, do, ds_end):
    b, s, h, p = r.shape
    cs = spec.chunk
    nc = s // cs

    rc, kc, vc, wc, doc = (_to_chunked(x, b, nc, cs, h, p)
                           for x in (r, k, v, wlog, do))

    def rev_map(bb, hh, ci):
        return (bb, hh, nc - 1 - ci, 0, 0)

    def u_map(bb, hh, ci):
        return (hh, 0)

    def pp_map(bb, hh, ci):
        return (bb, hh, 0, 0)

    def states_map(bb, hh, ci):
        return (bb, hh, nc - 1 - ci, 0, 0)

    dr, dk, dv, dw, ds0, du_bh = pl.pallas_call(
        functools.partial(_bwd_kernel, chunk=cs, num_chunks=nc),
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, cs, p), rev_map),
            pl.BlockSpec((1, 1, 1, cs, p), rev_map),
            pl.BlockSpec((1, 1, 1, cs, p), rev_map),
            pl.BlockSpec((1, 1, 1, cs, p), rev_map),
            pl.BlockSpec((1, p), u_map),
            pl.BlockSpec((1, 1, 1, p, p), states_map),
            pl.BlockSpec((1, 1, 1, cs, p), rev_map),
            pl.BlockSpec((1, 1, p, p), pp_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, cs, p), rev_map),
            pl.BlockSpec((1, 1, 1, cs, p), rev_map),
            pl.BlockSpec((1, 1, 1, cs, p), rev_map),
            pl.BlockSpec((1, 1, 1, cs, p), rev_map),
            pl.BlockSpec((1, 1, p, p), pp_map),
            pl.BlockSpec((1, 1, p), lambda bb, hh, ci: (bb, hh, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, cs, p), r.dtype),
            jax.ShapeDtypeStruct((b, h, nc, cs, p), k.dtype),
            jax.ShapeDtypeStruct((b, h, nc, cs, p), v.dtype),
            jax.ShapeDtypeStruct((b, h, nc, cs, p), wlog.dtype),
            jax.ShapeDtypeStruct((b, h, p, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, p), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((p, p), jnp.float32),
            pltpu.VMEM((1, p), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=spec.interpret,
    )(rc, kc, vc, wc, u, states, doc, ds_end)

    dr, dk, dv, dw = (_from_chunked(x, b, s, h, p)
                      for x in (dr, dk, dv, dw))
    du = jnp.sum(du_bh, axis=0)                    # fold batch outside
    return dr, dk, dv, dw, du, ds0


# ---------------------------------------------------------------------------
# custom VJP plumbing (shared kernels.vjp harness)
# ---------------------------------------------------------------------------

def _wkv_primal(spec, r, k, v, wlog, u, s0):
    o, s_end, _ = _forward(spec, r, k, v, wlog, u, s0, with_states=False)
    return o, s_end


def _wkv_fwd(spec, r, k, v, wlog, u, s0):
    o, s_end, states = _forward(spec, r, k, v, wlog, u, s0,
                                with_states=True)
    return (o, s_end), (r, k, v, wlog, u, s0, states)


def _wkv_bwd(spec, res, ct):
    r, k, v, wlog, u, s0, states = res
    do, ds_end = ct
    dr, dk, dv, dw, du, ds0 = _backward(
        spec, r, k, v, wlog, u, s0, states,
        do.astype(jnp.float32), ds_end.astype(jnp.float32))
    return vjp.cast_grads_like((dr, dk, dv, dw, du, ds0),
                               (r, k, v, wlog, u, s0))


_wkv = vjp.differentiable(_wkv_fwd, _wkv_bwd, primal=_wkv_primal)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_chunked_kernel(r, k, v, wlog, u, s0, *, chunk=32, interpret=False):
    """r/k/v/wlog (B, S, H, P); u (H, P); s0 (B, H, P, P).
    Returns (o (B,S,H,P) f32, s_end (B,H,P,P) f32). S % chunk must be 0
    (ops.py pads). Differentiable: custom VJP, Pallas backward kernel."""
    spec = _Spec(int(chunk), bool(interpret))
    return _wkv(spec, r, k, v, wlog, u, s0)
