"""WKV6 (RWKV6 recurrence) Pallas TPU kernel — chunked matmul form.

TPU adaptation (DESIGN.md §6): the reference CUDA wkv6 kernel serializes one
thread per channel over the whole sequence; here each (batch, head) runs a
sequential grid axis over chunks, carrying the (P, P) state in VMEM scratch,
while the intra-chunk work is two MXU matmuls + one VPU pairwise-decay
contraction. The pairwise decay exp(L_{t-1} - L_j) <= 1 for j < t, so the
kernel is fp32-overflow-safe under arbitrarily strong decay (unlike the
factored r·e^L / k·e^-L formulation).

Layout: r/k/v/wlog rearranged to (B, H, NC, CS, P) by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
            o_ref, s_out_ref, state_scr, *, chunk, num_chunks):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0, 0].astype(jnp.float32)         # (cs, P)
    k = k_ref[0, 0, 0].astype(jnp.float32)
    v = v_ref[0, 0, 0].astype(jnp.float32)
    w = w_ref[0, 0, 0].astype(jnp.float32)         # log-decay, <= 0
    u = u_ref[0].astype(jnp.float32)               # (P,)

    L = jnp.cumsum(w, axis=0)                      # inclusive
    lprev = L - w
    state = state_scr[...]

    # carried-state contribution
    o = jax.lax.dot(r * jnp.exp(lprev), state,
                    preferred_element_type=jnp.float32)

    # intra-chunk strictly-causal pairwise term (bounded decay <= 1)
    pair = jnp.exp(jnp.minimum(lprev[:, None, :] - L[None, :, :], 0.0))
    att = jnp.sum(r[:, None, :] * pair * k[None, :, :], axis=-1)  # (cs, cs)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(j_idx < t_idx, att, 0.0)
    o = o + jax.lax.dot(att, v, preferred_element_type=jnp.float32)

    # diagonal bonus
    diag = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True)
    o = o + diag * v

    # state update: S <- diag(e^{L_end}) S + (k ⊙ e^{L_end - L})^T v
    l_end = L[-1:, :]                              # (1, P)
    k_adv = k * jnp.exp(l_end - L)
    state_scr[...] = (jnp.exp(l_end).T * state
                      + jax.lax.dot(k_adv.T, v,
                                    preferred_element_type=jnp.float32))

    o_ref[0, 0, 0] = o.astype(o_ref.dtype)

    @pl.when(ci == num_chunks - 1)
    def _final():
        s_out_ref[0, 0] = state_scr[...].astype(s_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_chunked_kernel(r, k, v, wlog, u, s0, *, chunk=32, interpret=False):
    """r/k/v/wlog (B, S, H, P); u (H, P); s0 (B, H, P, P).
    Returns (o (B,S,H,P) f32, s_end (B,H,P,P) f32). S % chunk must be 0
    (ops.py pads)."""
    b, s, h, p = r.shape
    nc = s // chunk
    assert nc * chunk == s, (s, chunk)

    def to_bhncp(x):
        return x.reshape(b, nc, chunk, h, p).transpose(0, 3, 1, 2, 4)

    rc, kc, vc, wc = map(to_bhncp, (r, k, v, wlog))

    def rkvw_map(bb, hh, ci):
        return (bb, hh, ci, 0, 0)

    def u_map(bb, hh, ci):
        return (hh, 0)

    def s0_map(bb, hh, ci):
        return (bb, hh, 0, 0)

    o, s_end = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, num_chunks=nc),
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p), rkvw_map),
            pl.BlockSpec((1, 1, 1, chunk, p), rkvw_map),
            pl.BlockSpec((1, 1, 1, chunk, p), rkvw_map),
            pl.BlockSpec((1, 1, 1, chunk, p), rkvw_map),
            pl.BlockSpec((1, p), u_map),
            pl.BlockSpec((1, 1, p, p), s0_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p), rkvw_map),
            pl.BlockSpec((1, 1, p, p), s0_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, chunk, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, p, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, p), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(rc, kc, vc, wc, u, s0)

    o = o.transpose(0, 2, 3, 1, 4).reshape(b, s, h, p)
    return o, s_end
