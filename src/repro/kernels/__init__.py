"""Differentiable Pallas TPU kernel subsystem.

Custom kernels for the compute hot spots the paper's scaling results rest
on: flash attention (ViT/GQA/MLA train paths), the WKV6 recurrence (RWKV6),
and the fused RMSNorm that runs 2·L times per transformer step. On CPU
containers everything executes under ``interpret=True``; on TPU the same
kernels compile to Mosaic (``interpret=None`` auto-detects).

Kernel-authoring convention (enforced by review, reused by every kernel):

* **kernel module** (``flash_attention.py`` / ``wkv6.py`` / ``rmsnorm.py``)
  — Pallas forward AND backward kernels. Forward-only kernels are
  demo-tier; anything on a train path gets the full treatment.
* **ref oracle** (``ref.py``) — a pure-jnp definitional implementation.
  It is the allclose ground truth for outputs and, through ``jax.vjp``,
  for gradients.
* **custom VJP** (``vjp.py`` harness) — the kernel's static config rides a
  hashable spec as nondiff arg 0; the forward returns
  ``(primal, residuals)`` (inputs + cheap fp32 summaries: flash lse,
  rmsnorm inv-rms, wkv6 entering chunk states); backward kernels
  accumulate in fp32 VMEM scratch and cast to primal dtypes at the flush.
  ``jax.grad`` therefore never differentiates an interpreter/Mosaic body.
* **parity test** (``tests/test_flash_grad.py``,
  ``tests/test_kernel_grads.py``) — outputs and gradients vs the ref
  oracle, covering bf16 inputs, ragged tails, and the end-to-end
  ``use_pallas`` on/off train step.
* **dispatch** (``ops.py``) — the single surface the model layer imports;
  resolves tile sizes from ``ModelConfig`` and the interpret substrate.
"""
from repro.kernels.flash_attention import flash_attention_fwd, grid_cells
from repro.kernels.ops import flash_mha, fused_rmsnorm
from repro.kernels.rmsnorm import fused_rmsnorm_fwd
from repro.kernels.wkv6 import wkv6_chunked_kernel

# NOTE: ``kernels.flash_attention`` / ``kernels.wkv6`` (the *modules*) keep
# their names at package level, so the differentiable entry points of the
# same name are reached as module attributes or via the ops dispatch layer.
__all__ = [
    "flash_attention_fwd", "flash_mha", "fused_rmsnorm",
    "fused_rmsnorm_fwd", "grid_cells", "wkv6_chunked_kernel",
]
