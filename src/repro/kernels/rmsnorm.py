"""Fused RMSNorm Pallas TPU kernel — row-tiled, single HBM pass.

Unfused XLA emits separate reduce + mul passes over (tokens, d_model); the
fused kernel normalizes and scales one (block_rows, D) VMEM tile per grid
step. Trivial but hot: it runs 2·L times per transformer step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, scale_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = ((x / jnp.sqrt(var + eps))
                  * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def fused_rmsnorm(x, scale, *, eps=1e-6, block_rows=256, interpret=False):
    """x (..., D) -> rmsnorm(x) * scale, fused."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for dim in x.shape[:-1]:
        rows *= dim
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)
