"""Fused RMSNorm Pallas TPU kernels — row-tiled, single HBM pass,
forward + backward, custom VJP.

Unfused XLA emits separate reduce + mul passes over (tokens, d_model); the
fused kernel normalizes and scales one (block_rows, D) VMEM tile per grid
step. Trivial but hot: it runs 2·L times per transformer step, so the
backward matters more than the forward for training throughput.

Backward pass
-------------
``fused_rmsnorm`` is a ``jax.custom_vjp`` built on the shared
``kernels.vjp`` harness. The forward emits the per-row inverse RMS
``rinv = (mean(x²)+eps)^{-1/2}`` (fp32, one scalar per row) as a residual,
so the backward never redoes the row reduction: one row-tiled pass computes

    dx = rinv · (dy∘scale) − rinv³/D · x · rowsum(dy∘scale∘x)
    dscale = Σ_rows dy ∘ x ∘ rinv

with dscale accumulated across the whole (sequential) grid in an fp32 VMEM
scratch and flushed once at the last row-block. Ragged rows (rows %
block_rows ≠ 0) are masked out of the dscale reduction — OOB tile reads are
undefined (NaN in interpret mode) and would otherwise poison the
accumulator; the corresponding dx rows are clipped by the block writeback.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import vjp


class _Spec(NamedTuple):
    """Static kernel configuration (hashable: custom_vjp nondiff arg)."""
    block_rows: int
    eps: float
    interpret: bool


# ---------------------------------------------------------------------------
# forward kernel (emits per-row inv-rms residual)
# ---------------------------------------------------------------------------

def _fwd_kernel(x_ref, scale_ref, o_ref, rinv_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    rinv = 1.0 / jnp.sqrt(var + eps)               # (rows, 1) fp32
    o_ref[...] = ((x * rinv)
                  * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)
    rinv_ref[...] = rinv[:, 0]


def _forward(spec, x, scale):
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for dim in x.shape[:-1]:
        rows *= dim
    x2 = x.reshape(rows, d)
    br = min(spec.block_rows, rows)
    grid = (pl.cdiv(rows, br),)

    out, rinv = pl.pallas_call(
        functools.partial(_fwd_kernel, eps=spec.eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, d), x.dtype),
            jax.ShapeDtypeStruct((rows,), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=spec.interpret,
    )(x2, scale)
    return out.reshape(orig_shape), rinv


# ---------------------------------------------------------------------------
# backward kernel (row-tiled dx + grid-accumulated dscale)
# ---------------------------------------------------------------------------

def _bwd_kernel(x_ref, scale_ref, dy_ref, rinv_ref,
                dx_ref, dsc_ref, dsc_scr, *, dinv, rows, block_rows):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        dsc_scr[...] = jnp.zeros_like(dsc_scr)

    ok = vjp.row_valid(i, block_rows, rows)
    x = jnp.where(ok, x_ref[...].astype(jnp.float32), 0.0)
    dy = jnp.where(ok, dy_ref[...].astype(jnp.float32), 0.0)
    rinv = jnp.where(ok, rinv_ref[...][:, None], 0.0)   # (rows, 1)
    s = scale_ref[...].astype(jnp.float32)

    dys = dy * s[None, :]
    dot = jnp.sum(dys * x, axis=-1, keepdims=True)
    dx = rinv * dys - (rinv * rinv * rinv * dinv) * x * dot
    dx_ref[...] = dx.astype(dx_ref.dtype)
    dsc_scr[...] += jnp.sum(dy * x * rinv, axis=0, keepdims=True)

    @pl.when(i == pl.num_programs(0) - 1)
    def _final():
        dsc_ref[...] = dsc_scr[0].astype(dsc_ref.dtype)


def _backward(spec, x, scale, rinv, dy):
    orig_shape = x.shape
    d = x.shape[-1]
    rows = rinv.shape[0]
    x2 = x.reshape(rows, d)
    dy2 = dy.reshape(rows, d)
    br = min(spec.block_rows, rows)
    grid = (pl.cdiv(rows, br),)

    dx, dscale = pl.pallas_call(
        functools.partial(_bwd_kernel, dinv=1.0 / d, rows=rows,
                          block_rows=br),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, d), x.dtype),
            jax.ShapeDtypeStruct((d,), scale.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=spec.interpret,
    )(x2, scale, dy2, rinv)
    return dx.reshape(orig_shape), dscale


# ---------------------------------------------------------------------------
# custom VJP plumbing (shared kernels.vjp harness)
# ---------------------------------------------------------------------------

def _rms_fwd(spec, x, scale):
    out, rinv = _forward(spec, x, scale)
    return out, (x, scale, rinv)


def _rms_bwd(spec, res, dy):
    x, scale, rinv = res
    dx, dscale = _backward(spec, x, scale, rinv, dy)
    return dx, dscale


_rms = vjp.differentiable(_rms_fwd, _rms_bwd)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def fused_rmsnorm(x, scale, *, eps=1e-6, block_rows=256, interpret=False):
    """x (..., D) -> rmsnorm(x) * scale, fused. Differentiable (custom VJP,
    row-tiled Pallas backward reusing the saved per-row inv-rms)."""
    spec = _Spec(int(block_rows), float(eps), bool(interpret))
    return _rms(spec, x, scale)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def fused_rmsnorm_fwd(x, scale, *, eps=1e-6, block_rows=256,
                      interpret=False):
    """Forward returning ``(out, rinv)`` — the fp32 per-row inverse-RMS
    residual the backward consumes (exposed for tests/inspection)."""
    spec = _Spec(int(block_rows), float(eps), bool(interpret))
    return _forward(spec, x, scale)
