"""Shared custom-VJP harness for differentiable Pallas kernels.

Every train-path kernel in this package (flash attention, wkv6, fused
RMSNorm) follows the same pattern, extracted here so new kernels inherit it
instead of hand-rolling the plumbing:

* **Spec-as-nondiff-arg**: each kernel bundles its static configuration
  (block sizes, interpret flag, pruning switches) into a hashable NamedTuple
  passed as argument 0, declared ``nondiff_argnums=(0,)`` on the
  ``jax.custom_vjp`` and ``static_argnums=(0,)`` on the jit wrapper — one
  compiled kernel per spec, gradients never see it.
* **Residual plumbing**: the forward returns ``(primal, residuals)``; the
  harness registers it directly as the VJP fwd rule, so the Pallas forward
  decides exactly what survives to the backward (saved inputs + cheap fp32
  per-row/per-chunk summaries like the flash lse, the rmsnorm inv-rms, or
  the wkv6 entering chunk states) and ``jax.grad`` can never fall back to
  differentiating the interpreter/Mosaic kernel body.
* **fp32 accumulator policy**: backward kernels accumulate in
  ``ACCUM_DTYPE`` (fp32) VMEM scratch regardless of input dtype and cast to
  the primal dtype only at the final flush — ``cast_grads_like`` enforces
  the custom_vjp contract that each cotangent matches its primal's aval.
* **Interpret auto-detection**: ``auto_interpret(None)`` resolves to
  interpret mode off-TPU (this CPU container) and compiled Mosaic on TPU.
* **Block-size defaults from cfg**: ``attn_blocks`` / ``norm_block_rows`` /
  ``wkv_chunk`` pull tile sizes from a ``ModelConfig`` when one is in hand
  (the ops.py dispatch layer threads it through) with kernel-tuned
  fallbacks, so models never hardcode tile shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

ACCUM_DTYPE = jnp.float32

# VMEM bound on the wkv6 pairwise-decay tile (chunk, chunk, P); see
# configs/rwkv6_7b.py for the measurement that picked it.
WKV_CHUNK_MAX = 32


def auto_interpret(interpret=None) -> bool:
    """None -> interpret unless running on a real TPU backend."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def float0_like(x):
    """Zero cotangent for integer/meta operands (e.g. SMEM flag vectors)."""
    return np.zeros(np.shape(x), dtype=jax.dtypes.float0)


def row_valid(idx, block, limit):
    """(block, 1) bool: rows of tile ``idx`` inside a length-``limit`` axis.
    The shared ragged-tail mask — OOB block reads are undefined (NaN in
    interpret mode), so kernels zero the rows this marks False before any
    reduction/matmul touches them."""
    rows = idx * block + jax.lax.broadcasted_iota(jnp.int32, (block, 1), 0)
    return rows < limit


def cast_like(grad, primal):
    """Cast one fp32-accumulated gradient to its primal's dtype."""
    return grad.astype(primal.dtype)


def cast_grads_like(grads, primals):
    """Cast a tuple of fp32-accumulated gradients to the primal dtypes."""
    return tuple(cast_like(g, p) for g, p in zip(grads, primals))


def differentiable(fwd, bwd, primal=None):
    """Build a differentiable kernel op from a forward and a backward.

    ``fwd(spec, *args) -> (primal, residuals)`` — primal may be a pytree;
    residuals are whatever the backward needs (inputs + kernel-emitted
    summaries). ``bwd(spec, residuals, cotangent) -> grads`` — one per arg,
    ``float0_like`` for non-float operands. ``spec`` (argument 0) must be
    hashable; it is excluded from differentiation.

    ``primal(spec, *args) -> primal`` (optional): a residual-free forward
    for the non-differentiated path. Supply it when emitting residuals
    costs real HBM (e.g. the wkv6 per-chunk states) — XLA cannot dead-code
    an output out of a multi-output pallas_call, so eval/decode forwards
    would otherwise pay for residuals no backward ever reads.

    The returned op is NOT jitted — kernels wrap it with
    ``jax.jit(..., static_argnums=(0,))`` at their public entry point.
    """
    @functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
    def op(spec, *args):
        if primal is not None:
            return primal(spec, *args)
        return fwd(spec, *args)[0]

    op.defvjp(fwd, bwd)
    return op


# ---------------------------------------------------------------------------
# block-size defaults from cfg (the ops.py dispatch layer threads cfg here)
# ---------------------------------------------------------------------------

def attn_blocks(cfg=None, block_q=None, block_k=None):
    """(block_q, block_k) for the flash kernels: explicit > cfg > 128."""
    if block_q is None:
        block_q = cfg.attn_block_q if cfg is not None else 128
    if block_k is None:
        block_k = cfg.attn_block_k if cfg is not None else 128
    return int(block_q), int(block_k)


def norm_block_rows(cfg=None, block_rows=None):
    """Row-tile height for the fused-rmsnorm kernels: explicit > cfg > 256."""
    if block_rows is None:
        block_rows = getattr(cfg, "norm_block_rows", 256) \
            if cfg is not None else 256
    return int(block_rows)


def wkv_chunk(cfg=None, chunk=None):
    """wkv6 chunk length, clamped to the VMEM pairwise-tile bound."""
    if chunk is None:
        chunk = cfg.ssm.chunk_size if cfg is not None and cfg.ssm else \
            WKV_CHUNK_MAX
    return min(int(chunk), WKV_CHUNK_MAX)


__all__ = [
    "ACCUM_DTYPE", "WKV_CHUNK_MAX", "attn_blocks", "auto_interpret",
    "cast_grads_like", "cast_like", "differentiable", "float0_like",
    "norm_block_rows", "row_valid", "wkv_chunk",
]
