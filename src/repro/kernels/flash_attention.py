"""Flash attention Pallas TPU kernels — forward + backward, custom VJP.

TPU adaptation (DESIGN.md §6): the GPU flash algorithm's warp-level softmax
reductions become full-tile VPU reductions; tiles are MXU-aligned
(block_q × head_dim and block_k × head_dim multiples of 128 where the
head_dim allows). Forward grid = (batch, q_heads, q_blocks, k_blocks) with
the k-block axis innermost and sequential ("arbitrary"), carrying the
running max/denominator/accumulator in VMEM scratch. GQA is expressed in
the K/V BlockSpec index maps (kv_head = q_head // group), so no K/V
replication is materialized in HBM.

The sliding ``window`` and causal flags arrive as scalar-prefetch operands
(SMEM), keeping one compiled kernel for gemma3's per-layer local/global mix.

Backward pass (the training hot path)
-------------------------------------
``flash_attention`` is a ``jax.custom_vjp``: gradients never differentiate
through the interpreter/Mosaic forward. The forward additionally emits the
per-row logsumexp ``lse = m + log(l)`` (fp32, shape (B,H,S)) so the backward
recomputes probabilities directly as ``P = exp(S·scale − lse)`` without
re-running the online softmax. Two passes share the grid machinery:

* **dq pass** — grid (B, H, nq, nk), k innermost sequential. Per K-block:
  ``dP = dO·Vᵀ``, ``dS = P ∘ (dP − Δ)``, ``dq += scale · dS·K`` into an
  fp32 VMEM accumulator flushed at the last K-block. ``Δ = rowsum(dO ∘ O)``
  is a cheap elementwise XLA preprocess (fp32, shape (B,H,S)).
* **dk/dv pass** — grid (B, KH, nk, group, nq) with the (group, q_block)
  axes innermost-sequential, so dK/dV accumulate over every query head of
  the GQA group and every Q-block in fp32 VMEM scratch and are written once
  per K-block — the GQA reduction stays in the BlockSpec index maps, no
  (B,H,T,D) per-q-head gradient is ever materialized in HBM.

Block-skip masking: for causal / sliding-window layers, K-blocks that are
entirely masked for a Q-block (``k_min > q_max`` resp.
``q_min − k_max ≥ window``) early-exit via ``pl.when`` in forward and both
backward passes (~2× fewer tiles for causal, more for windowed layers);
fully-live interior blocks skip the iota/compare/select mask arithmetic via
``lax.cond``. The flags are traced scalars, so one compiled kernel serves
all layers; ``block_skip=False`` disables pruning for ablation.

Ragged tails (``s % block_q`` or ``t % block_k`` ≠ 0): out-of-bounds block
reads are undefined (NaN in interpret mode), so the tile masks include
bounds terms, probabilities are formed with NaN-discarding ``where``, and
tiles that feed a matmul against an exactly-zero factor (V in forward; Q,
dO, K, V in backward) are zeroed beyond the sequence edge — 0·NaN would
otherwise poison the accumulators. Fully-masked rows write
``lse = +LSE_BIG`` so the backward's ``exp(S − lse)`` underflows to 0.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30
LSE_BIG = 2.0 ** 30     # lse stand-in for fully-masked rows: exp(s-LSE_BIG)=0


class _Spec(NamedTuple):
    """Static kernel configuration (hashable: custom_vjp nondiff arg)."""
    block_q: int
    block_k: int
    interpret: bool
    block_skip: bool


# ---------------------------------------------------------------------------
# block-level predicates (traced: causal/window live in SMEM)
# ---------------------------------------------------------------------------

def _block_dead(causal, window, qi, ki, block_q, block_k):
    """True iff K-block ki is entirely masked for Q-block qi."""
    q_min = qi * block_q
    q_max = q_min + block_q - 1
    k_min = ki * block_k
    k_max = k_min + block_k - 1
    dead_causal = (causal > 0) & (k_min > q_max)
    dead_window = (window > 0) & ((q_min - k_max) >= window)
    return dead_causal | dead_window


def _block_needs_mask(causal, window, qi, ki, block_q, block_k, s, t):
    """False iff every (q,k) pair in the tile is live and in-bounds."""
    q_min = qi * block_q
    q_max = q_min + block_q - 1
    k_min = ki * block_k
    k_max = k_min + block_k - 1
    cut_causal = (causal > 0) & (k_max > q_min)
    cut_window = (window > 0) & ((q_max - k_min) >= window)
    ragged = (q_max >= s) | (k_max >= t)
    return cut_causal | cut_window | ragged


def _tile_mask(causal, window, qi, ki, block_q, block_k, s, t):
    """(block_q, block_k) bool mask: causal ∧ window ∧ bounds."""
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = (q_pos < s) & (k_pos < t)
    mask &= jnp.where(causal > 0, k_pos <= q_pos, True)
    mask &= jnp.where(window > 0, (q_pos - k_pos) < window, True)
    return mask


def _row_valid(idx, block, limit):
    """(block, 1) bool: rows of this tile that are inside the sequence."""
    rows = idx * block + jax.lax.broadcasted_iota(jnp.int32, (block, 1), 0)
    return rows < limit


# ---------------------------------------------------------------------------
# forward kernel (online softmax, emits lse residual)
# ---------------------------------------------------------------------------

def _fwd_kernel(meta_ref,            # SMEM scalar prefetch: [causal, window]
                q_ref, k_ref, v_ref,  # VMEM tiles
                o_ref, lse_ref,       # VMEM out tiles
                m_scr, l_scr, acc_scr,
                *, block_q, block_k, scale, num_k_blocks, seq_q, seq_k,
                block_skip):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    causal = meta_ref[0]
    window = meta_ref[1]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)        # (bk, d)
        # zero OOB V rows: P columns there are exactly 0 and 0*NaN = NaN
        v = jnp.where(_row_valid(ki, block_k, seq_k),
                      v_ref[0, 0].astype(jnp.float32), 0.0)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jax.lax.cond(
            _block_needs_mask(causal, window, qi, ki, block_q, block_k,
                              seq_q, seq_k),
            lambda x: jnp.where(_tile_mask(causal, window, qi, ki, block_q,
                                           block_k, seq_q, seq_k),
                                x, NEG_INF),
            lambda x: x, s)

        m_prev = m_scr[...]                        # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                     # (bq, bk)

        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if block_skip:
        pl.when(jnp.logical_not(
            _block_dead(causal, window, qi, ki, block_q, block_k)))(_compute)
    else:
        _compute()

    @pl.when(ki == num_k_blocks - 1)
    def _finish():
        m = m_scr[...]
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)
        # fully-masked rows (m never updated) get LSE_BIG so that the
        # backward's exp(s - lse) underflows to an exact 0
        lse = jnp.where(m > 0.5 * NEG_INF, m + jnp.log(l), LSE_BIG)
        lse_ref[0, 0] = lse[:, 0]


def _forward(spec, meta, q, k, v):
    b, h, s, d = q.shape
    kh, t = k.shape[1], k.shape[2]
    dv = v.shape[3]
    g = h // kh
    bq = min(spec.block_q, s)
    bk = min(spec.block_k, t)
    nq = pl.cdiv(s, bq)
    nk = pl.cdiv(t, bk)

    kernel = functools.partial(
        _fwd_kernel, block_q=bq, block_k=bk, scale=d ** -0.5,
        num_k_blocks=nk, seq_q=s, seq_k=t, block_skip=spec.block_skip)

    # index maps receive (*grid_indices, *scalar_prefetch_refs)
    def q_map(bb, hh, qi, ki, meta):
        return (bb, hh, qi, 0)

    def kv_map(bb, hh, qi, ki, meta):
        return (bb, hh // g, ki, 0)

    def lse_map(bb, hh, qi, ki, meta):
        return (bb, hh, qi)

    out, lse = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, h, nq, nk),
            in_specs=[
                pl.BlockSpec((1, 1, bq, d), q_map),
                pl.BlockSpec((1, 1, bk, d), kv_map),
                pl.BlockSpec((1, 1, bk, dv), kv_map),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, bq, dv), q_map),
                pl.BlockSpec((1, 1, bq), lse_map),
            ],
            scratch_shapes=[
                pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, dv), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, dv), q.dtype),
            jax.ShapeDtypeStruct((b, h, s), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=spec.interpret,
    )(meta, q, k, v)
    return out, lse


# ---------------------------------------------------------------------------
# backward kernels: recompute P from lse, fp32 accumulators
# ---------------------------------------------------------------------------

def _load_bwd_tiles(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    qi, ki, block_q, block_k, seq_q, seq_k):
    """Shared dq/dkv tile prologue: fp32 upcast with OOB rows zeroed (OOB
    block reads are undefined — NaN in interpret mode — and every tile here
    feeds a matmul whose other factor is exactly 0 in that region)."""
    kv_ok = _row_valid(ki, block_k, seq_k)
    q_ok = _row_valid(qi, block_q, seq_q)
    q = jnp.where(q_ok, q_ref[0, 0].astype(jnp.float32), 0.0)
    k = jnp.where(kv_ok, k_ref[0, 0].astype(jnp.float32), 0.0)
    v = jnp.where(kv_ok, v_ref[0, 0].astype(jnp.float32), 0.0)
    do = jnp.where(q_ok, do_ref[0, 0].astype(jnp.float32), 0.0)
    lse = lse_ref[0, 0][:, None]                   # (bq, 1)
    delta = delta_ref[0, 0][:, None]
    return q, k, v, do, lse, delta


def _recompute_p_ds(causal, window, qi, ki, block_q, block_k, seq_q, seq_k,
                    scale, s_, dp, lse, delta):
    """P = exp(S − lse); dS = scale · P ∘ (dP − Δ). Fully-live blocks skip
    the mask arithmetic (lax.cond); masked entries go through where() so
    NaN/inf from OOB reads never propagate."""
    def _with_mask(_):
        mask = _tile_mask(causal, window, qi, ki, block_q, block_k,
                          seq_q, seq_k)
        p = jnp.where(mask, jnp.exp(s_ - lse), 0.0)
        ds = jnp.where(mask, p * (dp - delta), 0.0) * scale
        return p, ds

    def _no_mask(_):
        p = jnp.exp(s_ - lse)
        return p, p * (dp - delta) * scale

    return jax.lax.cond(
        _block_needs_mask(causal, window, qi, ki, block_q, block_k,
                          seq_q, seq_k),
        _with_mask, _no_mask, None)


def _dq_kernel(meta_ref,
               q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
               dq_ref, dq_scr,
               *, block_q, block_k, scale, num_k_blocks, seq_q, seq_k,
               block_skip):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    causal = meta_ref[0]
    window = meta_ref[1]

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    def _compute():
        q, k, v, do, lse, delta = _load_bwd_tiles(
            q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
            qi, ki, block_q, block_k, seq_q, seq_k)
        s_ = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        _, ds = _recompute_p_ds(causal, window, qi, ki, block_q, block_k,
                                seq_q, seq_k, scale, s_, dp, lse, delta)
        dq_scr[...] += jax.lax.dot(ds, k,
                                   preferred_element_type=jnp.float32)

    if block_skip:
        pl.when(jnp.logical_not(
            _block_dead(causal, window, qi, ki, block_q, block_k)))(_compute)
    else:
        _compute()

    @pl.when(ki == num_k_blocks - 1)
    def _finish():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(meta_ref,
                q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr,
                *, block_q, block_k, scale, group, num_q_blocks, seq_q,
                seq_k, block_skip):
    ki = pl.program_id(2)
    gi = pl.program_id(3)
    qi = pl.program_id(4)
    causal = meta_ref[0]
    window = meta_ref[1]

    @pl.when((gi == 0) & (qi == 0))
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    def _compute():
        q, k, v, do, lse, delta = _load_bwd_tiles(
            q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
            qi, ki, block_q, block_k, seq_q, seq_k)
        s_ = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        p, ds = _recompute_p_ds(causal, window, qi, ki, block_q, block_k,
                                seq_q, seq_k, scale, s_, dp, lse, delta)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),       # pᵀ · dO  (bk, dv)
            preferred_element_type=jnp.float32)
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),       # dsᵀ · Q  (bk, d)
            preferred_element_type=jnp.float32)

    if block_skip:
        pl.when(jnp.logical_not(
            _block_dead(causal, window, qi, ki, block_q, block_k)))(_compute)
    else:
        _compute()

    @pl.when((gi == group - 1) & (qi == num_q_blocks - 1))
    def _finish():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def _backward(spec, meta, q, k, v, do, lse, delta):
    b, h, s, d = q.shape
    kh, t = k.shape[1], k.shape[2]
    dv_dim = v.shape[3]
    g = h // kh
    bq = min(spec.block_q, s)
    bk = min(spec.block_k, t)
    nq = pl.cdiv(s, bq)
    nk = pl.cdiv(t, bk)
    scale = d ** -0.5

    # ---- dq pass: grid (B, H, nq, nk), k innermost sequential ----
    def q_map(bb, hh, qi, ki, meta):
        return (bb, hh, qi, 0)

    def kv_map(bb, hh, qi, ki, meta):
        return (bb, hh // g, ki, 0)

    def lse_map(bb, hh, qi, ki, meta):
        return (bb, hh, qi)

    dq_kernel = functools.partial(
        _dq_kernel, block_q=bq, block_k=bk, scale=scale, num_k_blocks=nk,
        seq_q=s, seq_k=t, block_skip=spec.block_skip)

    dq = pl.pallas_call(
        dq_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, h, nq, nk),
            in_specs=[
                pl.BlockSpec((1, 1, bq, d), q_map),
                pl.BlockSpec((1, 1, bk, d), kv_map),
                pl.BlockSpec((1, 1, bk, dv_dim), kv_map),
                pl.BlockSpec((1, 1, bq, dv_dim), q_map),
                pl.BlockSpec((1, 1, bq), lse_map),
                pl.BlockSpec((1, 1, bq), lse_map),
            ],
            out_specs=pl.BlockSpec((1, 1, bq, d), q_map),
            scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=spec.interpret,
    )(meta, q, k, v, do, lse, delta)

    # ---- dk/dv pass: grid (B, KH, nk, group, nq); the (group, q_block)
    # axes are innermost-sequential so the fp32 scratch accumulates the
    # whole GQA group before one flush per K-block ----
    def q_map2(bb, kk, ki, gi, qi, meta):
        return (bb, kk * g + gi, qi, 0)

    def kv_map2(bb, kk, ki, gi, qi, meta):
        return (bb, kk, ki, 0)

    def lse_map2(bb, kk, ki, gi, qi, meta):
        return (bb, kk * g + gi, qi)

    dkv_kernel = functools.partial(
        _dkv_kernel, block_q=bq, block_k=bk, scale=scale, group=g,
        num_q_blocks=nq, seq_q=s, seq_k=t, block_skip=spec.block_skip)

    dk, dv = pl.pallas_call(
        dkv_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, kh, nk, g, nq),
            in_specs=[
                pl.BlockSpec((1, 1, bq, d), q_map2),
                pl.BlockSpec((1, 1, bk, d), kv_map2),
                pl.BlockSpec((1, 1, bk, dv_dim), kv_map2),
                pl.BlockSpec((1, 1, bq, dv_dim), q_map2),
                pl.BlockSpec((1, 1, bq), lse_map2),
                pl.BlockSpec((1, 1, bq), lse_map2),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, bk, d), kv_map2),
                pl.BlockSpec((1, 1, bk, dv_dim), kv_map2),
            ],
            scratch_shapes=[
                pltpu.VMEM((bk, d), jnp.float32),
                pltpu.VMEM((bk, dv_dim), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, kh, t, d), k.dtype),
            jax.ShapeDtypeStruct((b, kh, t, dv_dim), v.dtype),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary", "arbitrary")),
        interpret=spec.interpret,
    )(meta, q, k, v, do, lse, delta)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom VJP plumbing
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash(spec, meta, q, k, v):
    return _forward(spec, meta, q, k, v)[0]


def _flash_fwd_rule(spec, meta, q, k, v):
    out, lse = _forward(spec, meta, q, k, v)
    return out, (meta, q, k, v, out, lse)


def _flash_bwd_rule(spec, res, do):
    meta, q, k, v, out, lse = res
    delta = jnp.sum(out.astype(jnp.float32) * do.astype(jnp.float32),
                    axis=-1)                        # (B,H,S) fp32
    dq, dk, dv = _backward(spec, meta, q, k, v, do, lse, delta)
    dmeta = np.zeros(np.shape(meta), dtype=jax.dtypes.float0)
    return dmeta, dq, dk, dv


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def _meta(causal, window):
    return jnp.array([1 if causal else 0, 0], jnp.int32) \
        .at[1].set(jnp.asarray(window, jnp.int32))


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret", "block_skip"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=False, block_skip=True):
    """q (B,H,S,D), k/v (B,KH,T,D). window: int32 scalar (0=full, may be
    traced). Differentiable (custom VJP, Pallas backward kernels).
    Returns (B,H,S,D) in q.dtype."""
    spec = _Spec(block_q, block_k, interpret, block_skip)
    return _flash(spec, _meta(causal, window), q, k, v)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret", "block_skip"))
def flash_attention_fwd(q, k, v, *, causal=True, window=0, block_q=128,
                        block_k=128, interpret=False, block_skip=True):
    """Forward returning ``(out, lse)`` — the fp32 (B,H,S) logsumexp
    residual the backward consumes (exposed for tests/inspection)."""
    spec = _Spec(block_q, block_k, interpret, block_skip)
    return _forward(spec, _meta(causal, window), q, k, v)
