"""Flash attention Pallas TPU kernels — forward + backward, custom VJP.

TPU adaptation (DESIGN.md §6): the GPU flash algorithm's warp-level softmax
reductions become full-tile VPU reductions; tiles are MXU-aligned
(block_q × head_dim and block_k × head_dim multiples of 128 where the
head_dim allows). GQA is expressed in the K/V BlockSpec index maps
(kv_head = q_head // group), so no K/V replication is materialized in HBM.

The sliding ``window`` and causal flags arrive as scalar-prefetch operands
(SMEM), keeping one compiled kernel for gemma3's per-layer local/global mix.

Grid-level block pruning (index-map-level, the DMA saving)
----------------------------------------------------------
The (q_block, k_block) iteration space is flattened to a 1-D *cell* axis
enumerating only the block pairs that are live under the **statically known**
mask structure (the causal flag is always static; ``window`` too when passed
as a Python int). Three small int32 scalar-prefetch tables — cell→q_block,
cell→k_block, and first/last/dead-row flags — drive every BlockSpec index
map, so a skipped K-block is never DMA'd from HBM at all: the launched grid
shrinks (causal: nq·(nq+1)/2 of nq·nk cells), not just the executed FLOPs.
This is strictly stronger than the PR-1 scheme, which kept the dense grid
and early-exited via ``pl.when`` — saving the tile math but still paying the
HBM→VMEM copies the BlockSpec pipeline had already issued. When the window
is a *traced* scalar (gemma3's scan-over-layers), causal pruning still
shrinks the grid and the traced-window deadness falls back to the ``pl.when``
predicate inside the surviving cells; fully-live interior blocks skip the
iota/compare/select mask arithmetic via ``lax.cond``. ``block_skip=False``
restores the dense grid for ablation. The cell axis is innermost-sequential
("arbitrary"); batch and head stay parallel for megacore partitioning.

Statically-empty rows (e.g. K-rows beyond the causal horizon when t > s in
the dk/dv grid) get one sentinel *dead-row* cell that only zero-initializes
and flushes the output block, so every output tile is written exactly once.

Backward pass (the training hot path)
-------------------------------------
``flash_attention`` is a ``jax.custom_vjp`` built on the shared
``kernels.vjp`` harness: gradients never differentiate the
interpreter/Mosaic forward. The forward additionally emits the per-row
logsumexp ``lse = m + log(l)`` (fp32, shape (B,H,S)) so the backward
recomputes probabilities directly as ``P = exp(S·scale − lse)`` without
re-running the online softmax. Two passes share the grid machinery:

* **dq pass** — q-major pruned cells. The Δ = rowsum(dO ∘ O) preprocess is
  fused into the first cell of each q-row (an fp32 VMEM scratch reduction
  over the already-resident dO/O tiles — no separate XLA pass over
  (B,H,S,D)) and emitted as a (B,H,S) by-product for the dk/dv pass. Per
  K-cell: ``dP = dO·Vᵀ``, ``dS = P ∘ (dP − Δ)``, ``dq += scale · dS·K``
  into an fp32 VMEM accumulator flushed at the last cell of the row.
* **dk/dv pass** — k-major pruned cells over (k_block, group, q_block) with
  (group, q_block) innermost-sequential, so dK/dV accumulate over every
  query head of the GQA group and every live Q-block in fp32 VMEM scratch
  and are written once per K-block — the GQA reduction stays in the
  BlockSpec index maps, no (B,H,T,D) per-q-head gradient is ever
  materialized in HBM.

Ragged tails (``s % block_q`` or ``t % block_k`` ≠ 0): out-of-bounds block
reads are undefined (NaN in interpret mode), so the tile masks include
bounds terms, probabilities are formed with NaN-discarding ``where``, and
tiles that feed a matmul against an exactly-zero factor (V in forward; Q,
dO, O, K, V in backward) are zeroed beyond the sequence edge — 0·NaN would
otherwise poison the accumulators. Fully-masked rows write
``lse = +LSE_BIG`` so the backward's ``exp(S − lse)`` underflows to 0.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import vjp

NEG_INF = -2.0 ** 30
LSE_BIG = 2.0 ** 30     # lse stand-in for fully-masked rows: exp(s-LSE_BIG)=0

# cell-table flag bits
_FIRST = 1              # first cell of its output row: init accumulators
_LAST = 2               # last cell: flush accumulators to the output block
_DEAD = 4               # sentinel for a statically-empty row: zero-fill only


class _Spec(NamedTuple):
    """Static kernel configuration (hashable: custom_vjp nondiff arg).

    ``causal``/``window`` mirror the traced meta operands for grid pruning:
    ``window=None`` means the runtime value is traced (pruning then uses the
    causal structure only and defers window deadness to the in-kernel
    predicate)."""
    block_q: int
    block_k: int
    interpret: bool
    block_skip: bool
    causal: bool
    window: Optional[int]


# ---------------------------------------------------------------------------
# block-level predicates (traced: causal/window live in SMEM)
# ---------------------------------------------------------------------------

def _block_dead(causal, window, qi, ki, block_q, block_k):
    """True iff K-block ki is entirely masked for Q-block qi."""
    q_min = qi * block_q
    q_max = q_min + block_q - 1
    k_min = ki * block_k
    k_max = k_min + block_k - 1
    dead_causal = (causal > 0) & (k_min > q_max)
    dead_window = (window > 0) & ((q_min - k_max) >= window)
    return dead_causal | dead_window


def _block_needs_mask(causal, window, qi, ki, block_q, block_k, s, t):
    """False iff every (q,k) pair in the tile is live and in-bounds."""
    q_min = qi * block_q
    q_max = q_min + block_q - 1
    k_min = ki * block_k
    k_max = k_min + block_k - 1
    cut_causal = (causal > 0) & (k_max > q_min)
    cut_window = (window > 0) & ((q_max - k_min) >= window)
    ragged = (q_max >= s) | (k_max >= t)
    return cut_causal | cut_window | ragged


def _tile_mask(causal, window, qi, ki, block_q, block_k, s, t):
    """(block_q, block_k) bool mask: causal ∧ window ∧ bounds."""
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = (q_pos < s) & (k_pos < t)
    mask &= jnp.where(causal > 0, k_pos <= q_pos, True)
    mask &= jnp.where(window > 0, (q_pos - k_pos) < window, True)
    return mask


_row_valid = vjp.row_valid     # shared ragged-tail row mask (harness)


def _guard_compute(compute, flag, causal, window, qi, ki, block_q, block_k,
                   *, block_skip, static_window):
    """Run the tile body under the cheapest correct predicate: dense grids
    run unguarded; statically-pruned grids (window known at trace time)
    only need the dead-row sentinel check — every launched non-sentinel
    cell is live by construction of the host cell tables; traced-window
    grids re-check deadness from the SMEM scalars."""
    if not block_skip:
        compute()
    elif static_window:
        pl.when((flag & _DEAD) == 0)(compute)
    else:
        pl.when(jnp.logical_not(
            _block_dead(causal, window, qi, ki, block_q, block_k)
            | ((flag & _DEAD) != 0)))(compute)


# ---------------------------------------------------------------------------
# static cell enumeration (host ints -> SMEM prefetch tables)
# ---------------------------------------------------------------------------

def _host_dead(spec, qi, ki):
    """Host-int mirror of _block_dead under the *statically known* flags."""
    q_min = qi * spec.block_q
    q_max = q_min + spec.block_q - 1
    k_min = ki * spec.block_k
    k_max = k_min + spec.block_k - 1
    dead = spec.causal and (k_min > q_max)
    if spec.window is not None and spec.window > 0:
        dead = dead or (q_min - k_max) >= spec.window
    return dead


def _cells_q_major(spec, nq, nk):
    """(cq, ck, cflag) int32 tables for the fwd/dq grids: q-row-major live
    cells, one dead-row sentinel per statically-empty q-row."""
    cq, ck, cf = [], [], []
    for qi in range(nq):
        live = [ki for ki in range(nk)
                if not (spec.block_skip and _host_dead(spec, qi, ki))]
        if not live:
            cq.append(qi)
            ck.append(0)
            cf.append(_FIRST | _LAST | _DEAD)
            continue
        for j, ki in enumerate(live):
            cq.append(qi)
            ck.append(ki)
            cf.append((_FIRST if j == 0 else 0)
                      | (_LAST if j == len(live) - 1 else 0))
    return (np.asarray(cq, np.int32), np.asarray(ck, np.int32),
            np.asarray(cf, np.int32))


def _cells_k_major(spec, nq, nk, group):
    """(ck, cg, cq, cflag) tables for the dk/dv grid: k-row-major over
    (k_block, group, q_block); accumulators span a whole k-row."""
    ck, cg, cq, cf = [], [], [], []
    for ki in range(nk):
        live = [qi for qi in range(nq)
                if not (spec.block_skip and _host_dead(spec, qi, ki))]
        if not live:
            ck.append(ki)
            cg.append(0)
            cq.append(0)
            cf.append(_FIRST | _LAST | _DEAD)
            continue
        for gi in range(group):
            for j, qi in enumerate(live):
                ck.append(ki)
                cg.append(gi)
                cq.append(qi)
                cf.append(
                    (_FIRST if gi == 0 and j == 0 else 0)
                    | (_LAST if gi == group - 1 and j == len(live) - 1
                       else 0))
    return (np.asarray(ck, np.int32), np.asarray(cg, np.int32),
            np.asarray(cq, np.int32), np.asarray(cf, np.int32))


def grid_cells(s, t, *, causal, window=0, block_q=128, block_k=128,
               block_skip=True):
    """(launched, dense) q-major cell counts — the benchmark's DMA-pruning
    ablation reads the *actual* grid size the kernel launches."""
    spec = _Spec(min(block_q, s), min(block_k, t), True, block_skip,
                 bool(causal), int(window))
    nq = pl.cdiv(s, spec.block_q)
    nk = pl.cdiv(t, spec.block_k)
    return len(_cells_q_major(spec, nq, nk)[0]), nq * nk


# ---------------------------------------------------------------------------
# forward kernel (online softmax, emits lse residual)
# ---------------------------------------------------------------------------

def _fwd_kernel(meta_ref, cq_ref, ck_ref, cf_ref,  # SMEM scalar prefetch
                q_ref, k_ref, v_ref,  # VMEM tiles
                o_ref, lse_ref,       # VMEM out tiles
                m_scr, l_scr, acc_scr,
                *, block_q, block_k, scale, seq_q, seq_k, block_skip,
                static_window):
    c = pl.program_id(2)
    qi = cq_ref[c]
    ki = ck_ref[c]
    flag = cf_ref[c]
    causal = meta_ref[0]
    window = meta_ref[1]

    @pl.when((flag & _FIRST) != 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)        # (bk, d)
        # zero OOB V rows: P columns there are exactly 0 and 0*NaN = NaN
        v = jnp.where(_row_valid(ki, block_k, seq_k),
                      v_ref[0, 0].astype(jnp.float32), 0.0)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jax.lax.cond(
            _block_needs_mask(causal, window, qi, ki, block_q, block_k,
                              seq_q, seq_k),
            lambda x: jnp.where(_tile_mask(causal, window, qi, ki, block_q,
                                           block_k, seq_q, seq_k),
                                x, NEG_INF),
            lambda x: x, s)

        m_prev = m_scr[...]                        # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                     # (bq, bk)

        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    _guard_compute(_compute, flag, causal, window, qi, ki, block_q, block_k,
                   block_skip=block_skip, static_window=static_window)

    @pl.when((flag & _LAST) != 0)
    def _finish():
        m = m_scr[...]
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)
        # fully-masked rows (m never updated) get LSE_BIG so that the
        # backward's exp(s - lse) underflows to an exact 0
        lse = jnp.where(m > 0.5 * NEG_INF, m + jnp.log(l), LSE_BIG)
        lse_ref[0, 0] = lse[:, 0]


def _forward(spec, meta, q, k, v):
    b, h, s, d = q.shape
    kh, t = k.shape[1], k.shape[2]
    dv = v.shape[3]
    g = h // kh
    bq = min(spec.block_q, s)
    bk = min(spec.block_k, t)
    nq = pl.cdiv(s, bq)
    nk = pl.cdiv(t, bk)
    rspec = spec._replace(block_q=bq, block_k=bk)
    cq, ck, cf = (jnp.asarray(x) for x in _cells_q_major(rspec, nq, nk))

    kernel = functools.partial(
        _fwd_kernel, block_q=bq, block_k=bk, scale=d ** -0.5,
        seq_q=s, seq_k=t, block_skip=spec.block_skip,
        static_window=spec.window is not None)

    # index maps receive (*grid_indices, *scalar_prefetch_refs)
    def q_map(bb, hh, c, meta, cq, ck, cf):
        return (bb, hh, cq[c], 0)

    def kv_map(bb, hh, c, meta, cq, ck, cf):
        return (bb, hh // g, ck[c], 0)

    def lse_map(bb, hh, c, meta, cq, ck, cf):
        return (bb, hh, cq[c])

    out, lse = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(b, h, cq.shape[0]),
            in_specs=[
                pl.BlockSpec((1, 1, bq, d), q_map),
                pl.BlockSpec((1, 1, bk, d), kv_map),
                pl.BlockSpec((1, 1, bk, dv), kv_map),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, bq, dv), q_map),
                pl.BlockSpec((1, 1, bq), lse_map),
            ],
            scratch_shapes=[
                pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, dv), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, dv), q.dtype),
            jax.ShapeDtypeStruct((b, h, s), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=spec.interpret,
    )(meta, cq, ck, cf, q, k, v)
    return out, lse


# ---------------------------------------------------------------------------
# backward kernels: recompute P from lse, fp32 accumulators
# ---------------------------------------------------------------------------

def _load_bwd_tiles(q_ref, k_ref, v_ref, do_ref, lse_ref,
                    qi, ki, block_q, block_k, seq_q, seq_k):
    """Shared dq/dkv tile prologue: fp32 upcast with OOB rows zeroed (OOB
    block reads are undefined — NaN in interpret mode — and every tile here
    feeds a matmul whose other factor is exactly 0 in that region)."""
    kv_ok = _row_valid(ki, block_k, seq_k)
    q_ok = _row_valid(qi, block_q, seq_q)
    q = jnp.where(q_ok, q_ref[0, 0].astype(jnp.float32), 0.0)
    k = jnp.where(kv_ok, k_ref[0, 0].astype(jnp.float32), 0.0)
    v = jnp.where(kv_ok, v_ref[0, 0].astype(jnp.float32), 0.0)
    do = jnp.where(q_ok, do_ref[0, 0].astype(jnp.float32), 0.0)
    lse = lse_ref[0, 0][:, None]                   # (bq, 1)
    return q, k, v, do, lse


def _recompute_p_ds(causal, window, qi, ki, block_q, block_k, seq_q, seq_k,
                    scale, s_, dp, lse, delta):
    """P = exp(S − lse); dS = scale · P ∘ (dP − Δ). Fully-live blocks skip
    the mask arithmetic (lax.cond); masked entries go through where() so
    NaN/inf from OOB reads never propagate."""
    def _with_mask(_):
        mask = _tile_mask(causal, window, qi, ki, block_q, block_k,
                          seq_q, seq_k)
        p = jnp.where(mask, jnp.exp(s_ - lse), 0.0)
        ds = jnp.where(mask, p * (dp - delta), 0.0) * scale
        return p, ds

    def _no_mask(_):
        p = jnp.exp(s_ - lse)
        return p, p * (dp - delta) * scale

    return jax.lax.cond(
        _block_needs_mask(causal, window, qi, ki, block_q, block_k,
                          seq_q, seq_k),
        _with_mask, _no_mask, None)


def _dq_kernel(meta_ref, cq_ref, ck_ref, cf_ref,
               q_ref, k_ref, v_ref, do_ref, lse_ref, o_ref,
               dq_ref, delta_ref, dq_scr, delta_scr,
               *, block_q, block_k, scale, seq_q, seq_k, block_skip,
               static_window):
    c = pl.program_id(2)
    qi = cq_ref[c]
    ki = ck_ref[c]
    flag = cf_ref[c]
    causal = meta_ref[0]
    window = meta_ref[1]

    @pl.when((flag & _FIRST) != 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)
        # fused Δ = rowsum(dO ∘ O): the O/dO tiles are resident for this
        # q-row anyway, so the old standalone XLA pass over (B,H,S,D) folds
        # into one fp32 VPU reduction at the first cell of the row
        q_ok = _row_valid(qi, block_q, seq_q)
        o = jnp.where(q_ok, o_ref[0, 0].astype(jnp.float32), 0.0)
        do = jnp.where(q_ok, do_ref[0, 0].astype(jnp.float32), 0.0)
        delta_scr[...] = jnp.sum(o * do, axis=-1, keepdims=True)
        delta_ref[0, 0] = delta_scr[...][:, 0]

    def _compute():
        q, k, v, do, lse = _load_bwd_tiles(
            q_ref, k_ref, v_ref, do_ref, lse_ref,
            qi, ki, block_q, block_k, seq_q, seq_k)
        delta = delta_scr[...]
        s_ = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        _, ds = _recompute_p_ds(causal, window, qi, ki, block_q, block_k,
                                seq_q, seq_k, scale, s_, dp, lse, delta)
        dq_scr[...] += jax.lax.dot(ds, k,
                                   preferred_element_type=jnp.float32)

    _guard_compute(_compute, flag, causal, window, qi, ki, block_q, block_k,
                   block_skip=block_skip, static_window=static_window)

    @pl.when((flag & _LAST) != 0)
    def _finish():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(meta_ref, ck_ref, cg_ref, cq_ref, cf_ref,
                q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr,
                *, block_q, block_k, scale, seq_q, seq_k, block_skip,
                static_window):
    c = pl.program_id(2)
    ki = ck_ref[c]
    qi = cq_ref[c]
    flag = cf_ref[c]
    causal = meta_ref[0]
    window = meta_ref[1]

    @pl.when((flag & _FIRST) != 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    def _compute():
        q, k, v, do, lse = _load_bwd_tiles(
            q_ref, k_ref, v_ref, do_ref, lse_ref,
            qi, ki, block_q, block_k, seq_q, seq_k)
        delta = delta_ref[0, 0][:, None]           # (bq, 1)
        s_ = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        p, ds = _recompute_p_ds(causal, window, qi, ki, block_q, block_k,
                                seq_q, seq_k, scale, s_, dp, lse, delta)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),       # pᵀ · dO  (bk, dv)
            preferred_element_type=jnp.float32)
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),       # dsᵀ · Q  (bk, d)
            preferred_element_type=jnp.float32)

    _guard_compute(_compute, flag, causal, window, qi, ki, block_q, block_k,
                   block_skip=block_skip, static_window=static_window)

    @pl.when((flag & _LAST) != 0)
    def _finish():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def _backward_dq(spec, meta, q, k, v, do, out, lse):
    """dq pass over the q-major pruned cells; emits the fused Δ by-product
    the dk/dv pass consumes."""
    b, h, s, d = q.shape
    t = k.shape[2]
    dv_dim = v.shape[3]
    g = h // k.shape[1]
    bq = min(spec.block_q, s)
    bk = min(spec.block_k, t)
    nq = pl.cdiv(s, bq)
    nk = pl.cdiv(t, bk)
    rspec = spec._replace(block_q=bq, block_k=bk)
    cq, ck, cf = (jnp.asarray(x) for x in _cells_q_major(rspec, nq, nk))

    def q_map(bb, hh, c, meta, cq, ck, cf):
        return (bb, hh, cq[c], 0)

    def kv_map(bb, hh, c, meta, cq, ck, cf):
        return (bb, hh // g, ck[c], 0)

    def lse_map(bb, hh, c, meta, cq, ck, cf):
        return (bb, hh, cq[c])

    dq_kernel = functools.partial(
        _dq_kernel, block_q=bq, block_k=bk, scale=d ** -0.5,
        seq_q=s, seq_k=t, block_skip=spec.block_skip,
        static_window=spec.window is not None)

    dq, delta = pl.pallas_call(
        dq_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(b, h, cq.shape[0]),
            in_specs=[
                pl.BlockSpec((1, 1, bq, d), q_map),
                pl.BlockSpec((1, 1, bk, d), kv_map),
                pl.BlockSpec((1, 1, bk, dv_dim), kv_map),
                pl.BlockSpec((1, 1, bq, dv_dim), q_map),
                pl.BlockSpec((1, 1, bq), lse_map),
                pl.BlockSpec((1, 1, bq, dv_dim), q_map),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, bq, d), q_map),
                pl.BlockSpec((1, 1, bq), lse_map),
            ],
            scratch_shapes=[
                pltpu.VMEM((bq, d), jnp.float32),
                pltpu.VMEM((bq, 1), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, s), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=spec.interpret,
    )(meta, cq, ck, cf, q, k, v, do, lse, out)
    return dq, delta


def _backward_dkv(spec, meta, q, k, v, do, lse, delta):
    """dk/dv pass: k-major pruned cells over (k_block, group, q_block); the
    fp32 scratch accumulates the whole GQA group before one flush per
    K-block."""
    b, h, s, d = q.shape
    kh, t = k.shape[1], k.shape[2]
    dv_dim = v.shape[3]
    g = h // kh
    bq = min(spec.block_q, s)
    bk = min(spec.block_k, t)
    nq = pl.cdiv(s, bq)
    nk = pl.cdiv(t, bk)
    rspec = spec._replace(block_q=bq, block_k=bk)
    ck2, cg2, cq2, cf2 = (jnp.asarray(x)
                          for x in _cells_k_major(rspec, nq, nk, g))

    def q_map2(bb, kk, c, meta, ck, cg, cq, cf):
        return (bb, kk * g + cg[c], cq[c], 0)

    def kv_map2(bb, kk, c, meta, ck, cg, cq, cf):
        return (bb, kk, ck[c], 0)

    def lse_map2(bb, kk, c, meta, ck, cg, cq, cf):
        return (bb, kk * g + cg[c], cq[c])

    dkv_kernel = functools.partial(
        _dkv_kernel, block_q=bq, block_k=bk, scale=d ** -0.5,
        seq_q=s, seq_k=t, block_skip=spec.block_skip,
        static_window=spec.window is not None)

    dk, dv = pl.pallas_call(
        dkv_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=(b, kh, ck2.shape[0]),
            in_specs=[
                pl.BlockSpec((1, 1, bq, d), q_map2),
                pl.BlockSpec((1, 1, bk, d), kv_map2),
                pl.BlockSpec((1, 1, bk, dv_dim), kv_map2),
                pl.BlockSpec((1, 1, bq, dv_dim), q_map2),
                pl.BlockSpec((1, 1, bq), lse_map2),
                pl.BlockSpec((1, 1, bq), lse_map2),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, bk, d), kv_map2),
                pl.BlockSpec((1, 1, bk, dv_dim), kv_map2),
            ],
            scratch_shapes=[
                pltpu.VMEM((bk, d), jnp.float32),
                pltpu.VMEM((bk, dv_dim), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, kh, t, d), k.dtype),
            jax.ShapeDtypeStruct((b, kh, t, dv_dim), v.dtype),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=spec.interpret,
    )(meta, ck2, cg2, cq2, cf2, q, k, v, do, lse, delta)
    return dk, dv


# ---------------------------------------------------------------------------
# custom VJP plumbing (shared kernels.vjp harness)
# ---------------------------------------------------------------------------

def _flash_fwd(spec, meta, q, k, v):
    out, lse = _forward(spec, meta, q, k, v)
    return out, (meta, q, k, v, out, lse)


def _flash_bwd(spec, res, do):
    meta, q, k, v, out, lse = res
    dq, delta = _backward_dq(spec, meta, q, k, v, do, out, lse)
    dk, dv = _backward_dkv(spec, meta, q, k, v, do, lse, delta)
    return vjp.float0_like(meta), dq, dk, dv


_flash = vjp.differentiable(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def _meta(causal, window):
    return jnp.array([1 if causal else 0, 0], jnp.int32) \
        .at[1].set(jnp.asarray(window, jnp.int32))


def _make_spec(causal, window, block_q, block_k, interpret, block_skip):
    # window participates in static grid pruning only when it is a host int;
    # a traced window prunes on the causal structure and falls back to the
    # in-kernel predicate for window deadness
    wstat = int(window) if isinstance(window, (int, np.integer)) else None
    return _Spec(int(block_q), int(block_k), bool(interpret),
                 bool(block_skip), bool(causal), wstat)


@functools.partial(jax.jit, static_argnums=(0,))
def _flash_call(spec, meta, q, k, v):
    return _flash(spec, meta, q, k, v)


@functools.partial(jax.jit, static_argnums=(0,))
def _forward_call(spec, meta, q, k, v):
    return _forward(spec, meta, q, k, v)


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=False, block_skip=True):
    """q (B,H,S,D), k/v (B,KH,T,D). window: int (static -> grid pruning) or
    traced int32 scalar (0=full). Differentiable (custom VJP, Pallas
    backward kernels). Returns (B,H,S,D) in q.dtype."""
    spec = _make_spec(causal, window, block_q, block_k, interpret, block_skip)
    return _flash_call(spec, _meta(causal, window), q, k, v)


def flash_attention_fwd(q, k, v, *, causal=True, window=0, block_q=128,
                        block_k=128, interpret=False, block_skip=True):
    """Forward returning ``(out, lse)`` — the fp32 (B,H,S) logsumexp
    residual the backward consumes (exposed for tests/inspection)."""
    spec = _make_spec(causal, window, block_q, block_k, interpret, block_skip)
    return _forward_call(spec, _meta(causal, window), q, k, v)
