"""Flash attention Pallas TPU kernel — online-softmax, VMEM-tiled.

TPU adaptation (DESIGN.md §6): the GPU flash algorithm's warp-level softmax
reductions become full-tile VPU reductions; tiles are MXU-aligned
(block_q × head_dim and block_k × head_dim multiples of 128 where the
head_dim allows). Grid = (batch, q_heads, q_blocks, k_blocks) with the
k-block axis innermost and sequential ("arbitrary"), carrying the running
max/denominator/accumulator in VMEM scratch. GQA is expressed in the K/V
BlockSpec index maps (kv_head = q_head // group), so no K/V replication is
materialized in HBM.

The sliding ``window`` and causal flags arrive as scalar-prefetch operands
(SMEM), keeping one compiled kernel for gemma3's per-layer local/global mix.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _kernel(meta_ref,            # SMEM scalar prefetch: [causal, window]
            q_ref, k_ref, v_ref,  # VMEM tiles
            o_ref,                # VMEM out tile
            m_scr, l_scr, acc_scr,
            *, block_q, block_k, scale, num_k_blocks):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    causal = meta_ref[0]
    window = meta_ref[1]

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, dv)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = jnp.where(causal > 0, k_pos <= q_pos, True)
    mask &= jnp.where(window > 0, (q_pos - k_pos) < window, True)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                            # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                         # (bq, bk)

    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=False):
    """q (B,H,S,D), k/v (B,KH,T,D). window: int32 scalar (0=full, may be
    traced). Returns (B,H,S,D) in q.dtype."""
    b, h, s, d = q.shape
    kh, t = k.shape[1], k.shape[2]
    dv = v.shape[3]
    g = h // kh
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    nq = pl.cdiv(s, block_q)
    nk = pl.cdiv(t, block_k)

    meta = jnp.array([1 if causal else 0, 0], jnp.int32) \
        .at[1].set(jnp.asarray(window, jnp.int32))

    grid = (b, h, nq, nk)
    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, scale=d ** -0.5,
        num_k_blocks=nk)

    # index maps receive (*grid_indices, *scalar_prefetch_refs)
    def q_map(bb, hh, qi, ki, meta):
        return (bb, hh, qi, 0)

    def kv_map(bb, hh, qi, ki, meta):
        return (bb, hh // g, ki, 0)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, block_q, d), q_map),
                pl.BlockSpec((1, 1, block_k, d), kv_map),
                pl.BlockSpec((1, 1, block_k, dv), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, block_q, dv), q_map),
            scratch_shapes=[
                pltpu.VMEM((block_q, 1), jnp.float32),
                pltpu.VMEM((block_q, 1), jnp.float32),
                pltpu.VMEM((block_q, dv), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dv), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(meta, q, k, v)
    return out
