"""jit'd public wrappers around the Pallas kernels.

On this CPU-only container the kernels execute in ``interpret=True`` mode
(the kernel body runs in Python/XLA-CPU); on a real TPU backend they compile
to Mosaic. `interpret=None` auto-detects.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rmsnorm import fused_rmsnorm as _rmsnorm
from repro.kernels.wkv6 import wkv6_chunked_kernel as _wkv6


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def flash_mha(q, k, v, *, causal=True, window=0, block_q=128, block_k=128,
              interpret=None, block_skip=True):
    """q (B,S,H,D), k/v (B,T,KH,D) — model layout. GQA folded in-kernel.

    Differentiable: gradients route through the flash kernel's custom VJP
    (Pallas dq and dk/dv passes recomputing P from the saved fp32 lse) —
    ``jax.grad`` never differentiates the forward interpreter. The
    transposes here are linear, so the VJP composes transparently.
    ``block_skip`` prunes fully-masked K-blocks (causal/window)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _flash(qt, kt, vt, causal=causal, window=window, block_q=block_q,
                 block_k=block_k, interpret=_auto_interpret(interpret),
                 block_skip=block_skip)
    return out.transpose(0, 2, 1, 3)


def wkv6(r, k, v, wlog, u, s0, *, chunk=32, interpret=None):
    """r/k/v/wlog (B,S,H,P); pads S to a chunk multiple internally."""
    s = r.shape[1]
    pad = (-s) % chunk
    if pad:
        r, k, v = (jnp.pad(t, [(0, 0), (0, pad), (0, 0), (0, 0)])
                   for t in (r, k, v))
        wlog = jnp.pad(wlog, [(0, 0), (0, pad), (0, 0), (0, 0)])
    o, s_end = _wkv6(r, k, v, wlog, u, s0, chunk=chunk,
                     interpret=_auto_interpret(interpret))
    return o[:, :s], s_end


def fused_rmsnorm(x, scale, *, eps=1e-6, interpret=None):
    return _rmsnorm(x, scale, eps=eps,
                    interpret=_auto_interpret(interpret))
