"""Uniform dispatch layer over the differentiable Pallas kernels.

This is the ONE surface the model layer consumes kernels through
(``models/attention.py``, ``models/rwkv6.py``, ``models/norms.py``): every
op takes an optional ``cfg`` (a ``ModelConfig``) from which tile sizes are
resolved via the ``kernels.vjp`` defaults (``attn_block_q/attn_block_k``,
``norm_block_rows``, ``ssm.chunk_size``), and ``interpret=None``
auto-detects the substrate (interpret off-TPU, Mosaic on TPU).

Every op here is differentiable: gradients route through the kernels'
custom VJPs (Pallas backward passes) — ``jax.grad`` never differentiates a
forward interpreter body. Layout adapters in this file (transposes,
padding) are linear/XLA-differentiable, so they compose transparently with
the custom VJPs.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import vjp
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rmsnorm import fused_rmsnorm as _rmsnorm
from repro.kernels.wkv6 import wkv6_chunked_kernel as _wkv6


def flash_mha(q, k, v, *, causal=True, window=0, cfg=None, block_q=None,
              block_k=None, interpret=None, block_skip=True):
    """q (B,S,H,D), k/v (B,T,KH,D) — model layout. GQA folded in-kernel.

    Differentiable: gradients route through the flash kernel's custom VJP
    (Pallas dq and dk/dv passes recomputing P from the saved fp32 lse; the
    Δ preprocess is fused into the dq pass). ``block_skip`` prunes
    statically-dead K-blocks at the *grid* level (index-map pruning — the
    skipped blocks are never DMA'd) and traced-window deadness in-kernel."""
    bq, bk = vjp.attn_blocks(cfg, block_q, block_k)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _flash(qt, kt, vt, causal=causal, window=window, block_q=bq,
                 block_k=bk, interpret=vjp.auto_interpret(interpret),
                 block_skip=block_skip)
    return out.transpose(0, 2, 1, 3)


def wkv6(r, k, v, wlog, u, s0, *, cfg=None, chunk=None, interpret=None):
    """r/k/v/wlog (B,S,H,P); pads S to a chunk multiple internally (padded
    steps carry decay 1 / zero keys, so state and gradients pass through
    untouched). Differentiable via the wkv6 reverse-chunk backward kernel."""
    chunk = vjp.wkv_chunk(cfg, chunk)
    s = r.shape[1]
    pad = (-s) % chunk
    if pad:
        r, k, v = (jnp.pad(t, [(0, 0), (0, pad), (0, 0), (0, 0)])
                   for t in (r, k, v))
        wlog = jnp.pad(wlog, [(0, 0), (0, pad), (0, 0), (0, 0)])
    o, s_end = _wkv6(r, k, v, wlog, u, s0, chunk=chunk,
                     interpret=vjp.auto_interpret(interpret))
    return o[:, :s], s_end


def fused_rmsnorm(x, scale, *, eps=1e-6, cfg=None, block_rows=None,
                  interpret=None):
    """x (..., D) -> rmsnorm(x) * scale. Differentiable via the row-tiled
    dx/dscale backward kernel (saved per-row inv-rms residual)."""
    return _rmsnorm(x, scale, eps=eps,
                    block_rows=vjp.norm_block_rows(cfg, block_rows),
                    interpret=vjp.auto_interpret(interpret))
