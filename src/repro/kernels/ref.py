"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def ref_attention(q, k, v, *, causal=True, window=0):
    """Exact softmax attention. q (B,H,S,D), k/v (B,KH,T,D), GQA internal.
    window: 0 = full; >0 = sliding window (q_pos - k_pos < window)."""
    b, h, s, d = q.shape
    kh, t = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, kh, g, s, d)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * (d ** -0.5)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(t)[None, :]
    ok = jnp.ones((s, t), bool)
    if causal:
        ok &= kp <= qp
    if window:
        ok &= (qp - kp) < window
    scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", w.astype(v.dtype), v)
    return out.reshape(b, h, s, v.shape[-1])


def ref_wkv6(r, k, v, wlog, u, s0):
    """Sequential (per-step) WKV6 recurrence — the definitional oracle.

    r/k/v/wlog (B,S,H,P); u (H,P); s0 (B,H,P,P).
      o_t = r_t·(S_{t-1} + diag(u) k_t^T v_t);  S_t = diag(w_t) S_{t-1}+k_t^T v_t
    Returns (o (B,S,H,P), s_end).
    """
    f32 = jnp.float32
    r, k, v, wlog = (x.astype(f32) for x in (r, k, v, wlog))
    u = u.astype(f32)

    def step(S, inp):
        rt, kt, vt, wt = inp                     # (B,H,P)
        o = (jnp.einsum("bhp,bhpq->bhq", rt, S)
             + jnp.einsum("bhp,hp,bhp,bhq->bhq", rt, u, kt, vt))
        S = jnp.exp(wt)[..., None] * S + jnp.einsum("bhp,bhq->bhpq", kt, vt)
        return S, o

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (r, k, v, wlog))
    s_end, os_ = jax.lax.scan(step, s0.astype(f32), xs)
    return jnp.moveaxis(os_, 0, 1), s_end


def ref_rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf / jnp.sqrt(var + eps)) * scale.astype(jnp.float32)
            ).astype(x.dtype)
