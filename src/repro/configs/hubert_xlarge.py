"""HuBERT-XLarge — encoder-only audio transformer (w2v2 arch).
[arXiv:2106.07447]

Audio carve-out per brief: the mel-spectrogram + conv feature extractor is
STUBBED — ``input_specs()`` provides precomputed frame features
(B, S, audio_feat_dim) which the model linearly projects to d_model. Training
objective is masked prediction over a 504-class codebook (the HuBERT target
vocabulary). Encoder-only ⇒ no decode shapes (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "hubert-xlarge"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="audio",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab_size=504,
        causal=False,                 # encoder-only
        rope_style="none",            # w2v2 uses conv positional embeds; we
                                      # use learned absolute (stub frontend)
        audio_feat_dim=512,           # conv extractor output width
        norm_eps=1e-5,
        act="gelu",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name=ARCH_ID + "-smoke", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=4, head_dim=64, d_ff=512, vocab_size=64,
        audio_feat_dim=32)
