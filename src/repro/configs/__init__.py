"""Architecture registry: ``--arch <id>`` resolution.

10 assigned architectures (public-pool assignment) + the paper's own ViT-B/16.
"""
from __future__ import annotations

from repro.configs import (
    chatglm3_6b,
    deepseek_v3_671b,
    gemma3_12b,
    glm4_9b,
    granite_moe_3b,
    hubert_xlarge,
    qwen2_5_14b,
    qwen2_vl_72b,
    rwkv6_7b,
    vit_b16,
    zamba2_2_7b,
)
from repro.configs.base import EngineConfig, MeshConfig, ModelConfig
from repro.configs.shapes import SHAPES, InputShape, applicable, get_shape

_MODULES = (
    deepseek_v3_671b, qwen2_5_14b, qwen2_vl_72b, hubert_xlarge, glm4_9b,
    zamba2_2_7b, chatglm3_6b, gemma3_12b, rwkv6_7b, granite_moe_3b, vit_b16,
)

REGISTRY = {m.ARCH_ID: m for m in _MODULES}
ASSIGNED_ARCHS = tuple(m.ARCH_ID for m in _MODULES[:-1])  # excl. vit-b16
ALL_ARCHS = tuple(REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; choose from {ALL_ARCHS}")
    return REGISTRY[arch].config()


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; choose from {ALL_ARCHS}")
    return REGISTRY[arch].smoke()


__all__ = [
    "ALL_ARCHS", "ASSIGNED_ARCHS", "EngineConfig", "InputShape", "MeshConfig",
    "ModelConfig", "REGISTRY", "SHAPES", "applicable", "get_config",
    "get_shape", "get_smoke_config",
]
