"""RWKV6-7B "Finch" — attention-free, data-dependent decay.
[arXiv:2404.05892]

WKV6 recurrence with per-channel data-dependent decay (LoRA-projected),
token-shift mixing. O(1) decode state ⇒ long_500k runs.
"""
from repro.configs.base import ModelConfig, SSMConfig

ARCH_ID = "rwkv6-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="ssm",
        num_layers=32,
        d_model=4096,
        num_heads=64,                # d_model / head_size(=64)
        num_kv_heads=64,
        head_dim=64,
        d_ff=14336,
        vocab_size=65536,
        block_kind="rwkv6",
        # chunk 32 (not 128): §Perf — the XLA-path pairwise-decay tensor
        # scales with S*chunk*H*P; 32 measured 4.2x less HBM traffic than
        # 128 (and matches the Pallas kernel's VMEM tile budget)
        ssm=SSMConfig(state_dim=64, head_dim=64, chunk_size=32,
                      decay_lora=64),
        rope_style="none",
        norm_eps=1e-5,
        act="sqrelu",                # rwkv channel-mix uses squared relu
    )


def smoke() -> ModelConfig:
    return config().replace(
        name=ARCH_ID + "-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
        ssm=SSMConfig(state_dim=32, head_dim=32, chunk_size=32,
                      decay_lora=16))
