"""ChatGLM3-6B — dense, 2d (half) RoPE, GQA kv=2. [arXiv:2406.12793]"""
from repro.configs.base import ModelConfig

ARCH_ID = "chatglm3-6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        num_layers=28,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        head_dim=128,
        d_ff=13696,
        vocab_size=65024,
        qkv_bias=True,
        rope_style="half",
        rope_theta=10000.0,
        norm_eps=1e-5,
        act="swiglu",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name=ARCH_ID + "-smoke", num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=2, head_dim=32, d_ff=512, vocab_size=512)
