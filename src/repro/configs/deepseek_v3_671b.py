"""DeepSeek-V3 671B — MLA + 1 shared / 256 routed top-8 MoE + MTP.
[arXiv:2412.19437]

Assigned: 61L d_model=7168 128H (GQA kv=128) d_ff=2048 vocab=129280,
MoE 256e top-8. d_ff=2048 is the *expert* FFN width (moe_intermediate_size);
the first 3 dense layers use 18432 per the paper.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

ARCH_ID = "deepseek-v3-671b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="moe",
        num_layers=61,
        d_model=7168,
        num_heads=128,
        num_kv_heads=128,          # MLA: all heads read the shared latent
        head_dim=128,              # v head dim; qk = nope128 + rope64
        d_ff=18432,                # dense-layer FFN (first 3 layers)
        vocab_size=129280,
        block_kind="mla",
        rope_style="full",
        rope_theta=10000.0,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(num_experts=256, num_shared_experts=1, top_k=8,
                      d_ff_expert=2048, first_dense_layers=3,
                      router_aux_coef=0.001),
        mtp_depth=1,
        norm_eps=1e-6,
        act="swiglu",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        arch_type="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        block_kind="mla",
        mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                      qk_nope_head_dim=32, qk_rope_head_dim=16,
                      v_head_dim=32),
        moe=MoEConfig(num_experts=4, num_shared_experts=1, top_k=2,
                      d_ff_expert=64, first_dense_layers=1),
        mtp_depth=1,
        norm_eps=1e-6,
        act="swiglu",
    )
