"""Qwen2-VL-72B language backbone — M-RoPE, dynamic resolution.
[arXiv:2409.12191]

VLM carve-out per brief: the ViT vision tower + projector are STUBBED —
``input_specs()`` provides precomputed patch embeddings (B, vision_tokens,
d_model) and the 3D M-RoPE position grid (temporal, height, width sections).
This module is the 80-layer decoder that consumes them.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen2-vl-72b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="vlm",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        qkv_bias=True,
        rope_style="mrope",
        mrope_sections=(16, 24, 24),   # t/h/w split of the 64 rope pairs
        rope_theta=1000000.0,
        vision_tokens=1024,            # patch embeds per sample in input_specs
        norm_eps=1e-6,
        act="swiglu",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name=ARCH_ID + "-smoke", num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=2, head_dim=32, d_ff=512, vocab_size=512,
        mrope_sections=(4, 6, 6), vision_tokens=16)
