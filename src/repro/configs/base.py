"""Config system for the repro framework.

Mirrors the role of a DeepSpeed config JSON (the paper's Appendix B) plus a
model card: a frozen dataclass describing the architecture, and an
``EngineConfig`` describing the DeepSpeed-style distributed-training knobs
(train_batch_size / micro_batch_per_gpu / gradient_accumulation_steps /
zero_stage), which the paper's evaluation sweeps.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention dimensions."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0            # routed experts
    num_shared_experts: int = 0     # always-on shared experts
    top_k: int = 0
    d_ff_expert: int = 0            # per-expert FFN width
    first_dense_layers: int = 0     # leading dense layers (DeepSeek-V3: 3)
    router_aux_coef: float = 0.001  # load-balance loss coefficient
    capacity_factor: float = 1.25   # dropless in math; capacity for dispatch


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) / RWKV6 recurrent-block dimensions."""
    state_dim: int = 64             # N (mamba2) / head_size (rwkv6)
    head_dim: int = 64              # P per-head channel dim (mamba2)
    expand: int = 2                 # d_inner = expand * d_model (mamba2)
    conv_kernel: int = 4            # mamba2 short conv
    chunk_size: int = 128           # chunked-scan block length
    decay_lora: int = 64            # rwkv6 data-dependent decay bottleneck


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | vlm | audio | hybrid | ssm | vit
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # --- block structure -------------------------------------------------
    block_kind: str = "attn"        # attn | mla | mamba2 | rwkv6
    # hybrid (zamba2): `hybrid_group` mamba layers share one attention block
    hybrid_group: int = 0           # 0 = not hybrid
    causal: bool = True             # False for encoder-only (hubert)

    # --- attention flavour ------------------------------------------------
    qkv_bias: bool = False
    rope_style: str = "full"        # full | half | mrope | none
    rope_theta: float = 10000.0
    sliding_window: int = 0         # 0 = full attention
    global_every: int = 0           # gemma3: every Nth layer full, rest local
    attn_logit_softcap: float = 0.0

    # --- sub-configs -------------------------------------------------------
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # --- embeddings / head --------------------------------------------
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    act: str = "swiglu"             # swiglu | gelu | geglu | sqrelu
    mtp_depth: int = 0              # DeepSeek-V3 multi-token prediction heads
    embed_scale: bool = False       # gemma-style sqrt(d) embedding scale

    # --- modality frontends (STUBBED per brief) ------------------------
    # audio: input is (B, S, audio_feat_dim) precomputed conv features
    audio_feat_dim: int = 0
    # vlm: input_specs feeds (B, n_img, d_model) patch embeddings + M-RoPE grid
    vision_tokens: int = 0          # image tokens per sample in input_specs
    mrope_sections: Tuple[int, int, int] = (0, 0, 0)

    # --- ViT (the paper's own model) -----------------------------------
    image_size: int = 0
    patch_size: int = 0
    num_classes: int = 0
    label_smoothing: float = 0.0    # classification CE smoothing (train
    #                                 only; eval NLL stays un-smoothed)

    # --- numerics -------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    use_pallas: bool = False        # Pallas kernels (TPU; interpret on CPU)
    attn_impl: str = "naive"        # naive | blockwise (flash-in-XLA)
    moe_impl: str = "gshard"        # gshard (einsum) | gather (§Perf)
    attn_block_k: int = 512
    attn_block_q: int = 512
    norm_block_rows: int = 256      # fused-rmsnorm row-tile height
    remat: str = "none"             # none | block  (activation checkpointing)

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads > 0 and self.num_kv_heads > 0:
            assert self.num_heads % self.num_kv_heads == 0, (
                f"{self.name}: num_heads {self.num_heads} not divisible by "
                f"kv heads {self.num_kv_heads}")

    # ------------------------------------------------------------------
    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def is_attention_free(self) -> bool:
        return self.block_kind in ("mamba2", "rwkv6")

    def supports_long_decode(self) -> bool:
        """True if decode state is sub-linear in context (SSM/hybrid) or the
        attention is sliding-window (bounded local KV)."""
        return self.is_attention_free or self.hybrid_group > 0 or \
            self.sliding_window > 0

    def supports_decode(self) -> bool:
        return not self.is_encoder_only and self.arch_type != "vit"

    def layer_windows(self):
        """Per-layer sliding window (0=full) honoring gemma3 local:global."""
        if self.sliding_window == 0:
            return [0] * self.num_layers
        if self.global_every <= 0:
            return [self.sliding_window] * self.num_layers
        return [0 if (i + 1) % self.global_every == 0 else self.sliding_window
                for i in range(self.num_layers)]

    # --- parameter counting (for roofline MODEL_FLOPS) -----------------
    def param_count(self) -> int:
        from repro.models.params import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.params import count_params_analytic
        return count_params_analytic(self, active_only=True)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Engine (DeepSpeed-equivalent) configuration — the paper's Appendix B knobs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class EngineConfig:
    """DeepSpeed-style engine config.

    Invariant (DeepSpeed semantics, enforced):
        train_batch_size ==
            micro_batch_per_gpu * gradient_accumulation_steps * dp_world_size
    """
    train_batch_size: int = 32
    micro_batch_per_gpu: int = 0        # 0 -> derived
    gradient_accumulation_steps: int = 1
    zero_stage: int = 0                 # 0=DDP (paper), 1, 2, 3(FSDP)
    optimizer: str = "adamw"            # adamw | sgd | lamb
    lr: float = 3e-4
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    lr_schedule: str = "cosine"
    total_steps: int = 1000
    seed: int = 0
    # parallelism (beyond-paper: TP / Ulysses SP on the `model` axis)
    tensor_parallel: bool = True
    sequence_parallel: str = "none"     # none | ulysses
    expert_parallel: bool = True
    # pipeline parallelism over the `pipe` mesh axis (core/pipeline.py):
    # 1F1B microbatch schedule; microbatches come from
    # gradient_accumulation_steps, so accum >= pipeline_stages is required.
    # pipeline_interleave = v virtual stage-chunks per device (Megatron
    # interleaved 1F1B); v > 1 additionally requires accum % stages == 0
    pipeline_stages: int = 1
    pipeline_interleave: int = 1
    cast_params_bf16: bool = False      # §Perf: bf16 gather, f32 master
    embed_sharding: str = "vocab"       # vocab | dmodel (§Perf)
    # elastic checkpointing (repro.checkpoint): cadence in optimizer steps
    # (0 = end-of-run only) and the async saver's bounded in-flight count
    ckpt_every: int = 0
    ckpt_async: bool = True
    ckpt_max_in_flight: int = 2
    # retention GC: keep the newest K checkpoints (0 = keep all); the GC
    # never deletes the newest step that passes verification
    ckpt_keep_last: int = 0
    # anomaly guard (resilience): in-jit finite checks on loss and global
    # grad-norm produce a step_ok metric; a non-finite step SKIPS the
    # optimizer update (params/opt/step unchanged — the host loop retries
    # the same cursor batch and escalates to an error after
    # guard_max_skips consecutive skips)
    guard_anomalies: bool = True
    guard_max_skips: int = 3

    def derived_micro_batch(self, dp_world: int) -> int:
        if self.micro_batch_per_gpu:
            return self.micro_batch_per_gpu
        mb, rem = divmod(self.train_batch_size,
                         self.gradient_accumulation_steps * dp_world)
        if rem:
            raise ValueError(
                f"train_batch_size={self.train_batch_size} not divisible by "
                f"accum={self.gradient_accumulation_steps} * dp={dp_world}")
        return mb

    def validate(self, dp_world: int) -> None:
        mb = self.derived_micro_batch(dp_world)
        got = mb * self.gradient_accumulation_steps * dp_world
        if got != self.train_batch_size:
            raise ValueError(
                "DeepSpeed batch invariant violated: "
                f"{mb} * {self.gradient_accumulation_steps} * {dp_world} "
                f"= {got} != train_batch_size={self.train_batch_size}")
        if self.pipeline_stages > 1:
            # 1F1B fill/drain needs at least one microbatch per stage
            if self.gradient_accumulation_steps < self.pipeline_stages:
                raise ValueError(
                    "1F1B needs microbatch count >= pipeline depth: "
                    f"gradient_accumulation_steps="
                    f"{self.gradient_accumulation_steps} < pipeline_stages="
                    f"{self.pipeline_stages}")
            if self.sequence_parallel != "none":
                raise ValueError(
                    "pipeline_stages > 1 does not compose with Ulysses "
                    "sequence parallelism yet")
        if self.pipeline_interleave < 1:
            raise ValueError(
                f"pipeline_interleave must be >= 1: "
                f"{self.pipeline_interleave}")
        if self.pipeline_interleave > 1:
            if self.pipeline_stages <= 1:
                raise ValueError(
                    "pipeline_interleave > 1 requires pipeline_stages > 1")
            if self.gradient_accumulation_steps % self.pipeline_stages:
                # Megatron interleaved 1F1B groups microbatches in runs
                # of S per chunk round
                raise ValueError(
                    "interleaved 1F1B needs microbatch count divisible by "
                    "pipeline depth: gradient_accumulation_steps="
                    f"{self.gradient_accumulation_steps} % pipeline_stages="
                    f"{self.pipeline_stages} != 0")
        if self.ckpt_every < 0:
            raise ValueError(
                f"ckpt_every must be >= 0 (0 = end-of-run only): "
                f"{self.ckpt_every}")
        if self.ckpt_max_in_flight < 1:
            raise ValueError(
                f"ckpt_max_in_flight must be >= 1: "
                f"{self.ckpt_max_in_flight}")
        if self.ckpt_keep_last < 0:
            raise ValueError(
                f"ckpt_keep_last must be >= 0 (0 = keep all): "
                f"{self.ckpt_keep_last}")
        if self.guard_max_skips < 1:
            raise ValueError(
                f"guard_max_skips must be >= 1: {self.guard_max_skips}")

    def replace(self, **kw) -> "EngineConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class MeshConfig:
    """Production mesh axes. `pod` is the DCN (inter-pod) axis."""
    data: int = 16
    model: int = 16
    pod: int = 1

    @property
    def num_devices(self) -> int:
        return self.data * self.model * self.pod

    @property
    def dp_world(self) -> int:
        # gradients reduce over data AND pod axes (hierarchical all-reduce)
        return self.data * self.pod

    @property
    def axis_names(self):
        return ("pod", "data", "model") if self.pod > 1 else ("data", "model")

    @property
    def shape(self):
        return ((self.pod, self.data, self.model) if self.pod > 1
                else (self.data, self.model))
