"""Gemma3-12B — dense, 5:1 local:global sliding window, 128k context.
[hf:google/gemma-3-1b-pt family]

Every 6th layer is global attention; the other 5 use a 1024-token sliding
window. The sliding-window variant bounds local-layer KV, which is how
long_500k decode runs for this dense arch (DESIGN.md §4) — global layers
keep full KV (1/6 of layers).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "gemma3-12b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        num_layers=48,
        d_model=3840,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,                # gemma3 decouples head_dim from d_model
        d_ff=15360,
        vocab_size=262144,
        rope_style="full",
        rope_theta=1000000.0,
        sliding_window=1024,
        global_every=6,              # 5 local : 1 global
        attn_logit_softcap=0.0,
        tie_embeddings=True,
        embed_scale=True,
        norm_eps=1e-6,
        act="geglu",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name=ARCH_ID + "-smoke", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512,
        sliding_window=64, global_every=2)
