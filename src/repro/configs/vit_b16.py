"""ViT-B/16 — the paper's own model [Dosovitskiy et al., 2021].

86M-parameter encoder used for CIFAR-10/100 classification in the paper's
evaluation. The classification variant patchifies images directly (conv
patch embed implemented, not stubbed — this is the paper's actual workload).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "vit-b16"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="vit",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab_size=0,
        causal=False,
        rope_style="none",
        image_size=224,
        patch_size=16,
        num_classes=10,              # CIFAR-10 default; overridden per dataset
        norm_eps=1e-6,
        act="gelu",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name=ARCH_ID + "-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, head_dim=32, d_ff=256,
        image_size=32, patch_size=4, num_classes=10)
