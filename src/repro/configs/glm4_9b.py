"""GLM-4-9B — dense, RoPE, GQA kv=2. [hf:THUDM/glm-4-9b]"""
from repro.configs.base import ModelConfig

ARCH_ID = "glm4-9b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        head_dim=128,
        d_ff=13696,
        vocab_size=151552,
        qkv_bias=True,
        rope_style="half",           # GLM rotary on half the head dims
        rope_theta=10000.0,
        norm_eps=1.5625e-7,
        act="swiglu",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name=ARCH_ID + "-smoke", num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=2, head_dim=32, d_ff=512, vocab_size=512,
        norm_eps=1e-6)
