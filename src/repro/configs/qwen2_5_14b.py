"""Qwen2.5-14B — dense, GQA kv=8, QKV bias. [hf:Qwen/Qwen2.5-0.5B family]"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen2.5-14b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=13824,
        vocab_size=152064,
        qkv_bias=True,
        rope_style="full",
        rope_theta=1000000.0,
        norm_eps=1e-6,
        act="swiglu",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name=ARCH_ID + "-smoke", num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=2, head_dim=32, d_ff=512, vocab_size=512)
