"""Zamba2-2.7B — hybrid: Mamba2 backbone + shared attention block.
[arXiv:2411.15242]

54 Mamba2 layers; one *weight-shared* attention(+MLP) block is interleaved
every ``hybrid_group`` Mamba layers (Zamba2's "shared attention" design —
the same attention weights are re-applied at each interleave point).
SSM state ⇒ long_500k decode runs (O(1) per-token state).
"""
from repro.configs.base import ModelConfig, SSMConfig

ARCH_ID = "zamba2-2.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        head_dim=80,
        d_ff=10240,
        vocab_size=32000,
        block_kind="mamba2",
        hybrid_group=6,              # shared attn block every 6 mamba layers
        # chunk 128 kept: §Perf measured chunk 32 WORSE here (1637 vs
        # 1369s) — mamba2's intra-chunk tensors are (c,c,H), an H-fold
        # smaller footprint than rwkv6's (c,c,H,P), so smaller chunks only
        # add per-chunk overhead
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_kernel=4,
                      chunk_size=128),
        rope_style="full",
        norm_eps=1e-5,
        act="swiglu",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name=ARCH_ID + "-smoke", num_layers=4, d_model=128, num_heads=4,
        num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
        hybrid_group=2,
        ssm=SSMConfig(state_dim=16, head_dim=32, expand=2, conv_kernel=4,
                      chunk_size=32))
