"""Granite-MoE 3B-A800M — 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base family]
"""
from repro.configs.base import ModelConfig, MoEConfig

ARCH_ID = "granite-moe-3b-a800m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="moe",
        num_layers=32,
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,
        head_dim=64,
        d_ff=512,                    # per-expert FFN width (assigned)
        vocab_size=49155,
        rope_style="full",
        rope_theta=10000.0,
        moe=MoEConfig(num_experts=40, num_shared_experts=0, top_k=8,
                      d_ff_expert=512, first_dense_layers=0,
                      router_aux_coef=0.01),
        tie_embeddings=True,
        norm_eps=1e-6,
        act="swiglu",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name=ARCH_ID + "-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=64, vocab_size=512,
        moe=MoEConfig(num_experts=4, num_shared_experts=0, top_k=2,
                      d_ff_expert=64, first_dense_layers=0))
