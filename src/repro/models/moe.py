"""Mixture-of-Experts: token-choice top-k router, GShard-style einsum
dispatch/combine (TPU-idiomatic — shards to all_to_all under expert
parallelism), shared experts, switch-style load-balance aux loss.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import shardctx
from repro.models.params import dense_init


def init_moe(key, cfg):
    m, d = cfg.moe, cfg.d_model
    ks = jax.random.split(key, 7)
    e, f = m.num_experts, m.d_ff_expert
    p = {
        "router": dense_init(ks[0], (d, e), scale=0.02),
        "experts": {
            "w_gate": dense_init(ks[1], (e, d, f)),
            "w_up": dense_init(ks[2], (e, d, f)),
            "w_out": dense_init(ks[3], (e, f, d)),
        },
    }
    if m.num_shared_experts > 0:
        fs = f * m.num_shared_experts
        p["shared"] = {
            "w_gate": dense_init(ks[4], (d, fs)),
            "w_up": dense_init(ks[5], (d, fs)),
            "w_out": dense_init(ks[6], (fs, d)),
        }
    return p


def top_k_routing(logits, k, capacity):
    """GShard dense dispatch.

    logits (B, S, E) fp32. Returns (dispatch (B,S,E,C) bool-ish float,
    combine (B,S,E,C) float, aux_loss scalar).
    """
    b, s, e = logits.shape
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (B,S,k)

    # position of each (token, choice) in its expert's capacity buffer
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)  # (B,S,k,E)
    # priority: choice-major then sequence order (GShard convention)
    flat = onehot.transpose(0, 2, 1, 3).reshape(b, k * s, e)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat          # (B,k*S,E)
    pos = pos_in_expert.reshape(b, k, s, e).transpose(0, 2, 1, 3)  # (B,S,k,E)
    pos = jnp.sum(pos * onehot, axis=-1)                     # (B,S,k)
    keep = pos < capacity

    cap_onehot = jax.nn.one_hot(pos, capacity) * keep[..., None]
    # (B,S,k,E) x (B,S,k,C) -> (B,S,E,C)
    dispatch = jnp.einsum("bske,bskc->bsec", onehot.astype(jnp.float32),
                          cap_onehot)
    combine = jnp.einsum("bsk,bske,bskc->bsec", gate_vals,
                         onehot.astype(jnp.float32), cap_onehot)

    # switch-style load-balance loss
    me = jnp.mean(jax.nn.one_hot(expert_idx, e).sum(2), axis=(0, 1)) / k
    ce = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(me * ce)
    return dispatch, combine, aux


def moe_ffn(p, x, cfg):
    """x (B, S, D) -> (out (B, S, D), aux_loss)."""
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    capacity = max(1, int(math.ceil(s * k / e * m.capacity_factor)))

    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    dispatch, combine, aux = top_k_routing(logits, k, capacity)
    dispatch = dispatch.astype(x.dtype)
    combine = combine.astype(x.dtype)

    expert_in = jnp.einsum("bsec,bsd->ebcd", dispatch, x)
    ep_axis = shardctx.get().expert
    if ep_axis is not None:
        expert_in = shardctx.constrain(
            expert_in, jax.sharding.PartitionSpec(ep_axis))

    we = p["experts"]
    h = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", expert_in,
                               we["w_gate"].astype(x.dtype)))
    h = h * jnp.einsum("ebcd,edf->ebcf", expert_in,
                       we["w_up"].astype(x.dtype))
    expert_out = jnp.einsum("ebcf,efd->ebcd", h, we["w_out"].astype(x.dtype))
    if ep_axis is not None:
        expert_out = shardctx.constrain(
            expert_out, jax.sharding.PartitionSpec(ep_axis))

    out = jnp.einsum("bsec,ebcd->bsd", combine, expert_out)

    if "shared" in p:
        sh = p["shared"]
        hs = jax.nn.silu(x @ sh["w_gate"].astype(x.dtype)) * (
            x @ sh["w_up"].astype(x.dtype))
        out = out + hs @ sh["w_out"].astype(x.dtype)
    return out, m.router_aux_coef * aux


# ---------------------------------------------------------------------------
# gather/scatter dispatch (beyond-paper optimization, §Perf)
# ---------------------------------------------------------------------------

def moe_ffn_gather(p, x, cfg):
    """Gather-based MoE dispatch.

    The GShard einsum dispatch above costs O(T·E·C·D) MXU flops — for
    DeepSeek-V3 (E=256) that *exceeds* the expert FFN flops and dominates
    the compute roofline term. This path builds an (E, C) slot→token index
    table and uses gather/scatter instead: O(T·k·D) data movement, zero
    dispatch flops. Capacity priority is flat token-major (vs. GShard's
    choice-major) — identical when capacity is ample.

    Selected with cfg.moe_impl == "gather".
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.num_experts, m.top_k
    capacity = max(1, int(math.ceil(t * k / e * m.capacity_factor)))

    x_flat = x.reshape(t, d)
    logits = x_flat.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (T, k)

    ek = expert_idx.reshape(t * k)
    gates = gate_vals.reshape(t * k).astype(x.dtype)
    tok_ids = jnp.arange(t * k, dtype=jnp.int32) // k

    oh = jax.nn.one_hot(ek, e, dtype=jnp.int32)              # (T*k, E)
    pos = jnp.cumsum(oh, axis=0) - oh
    pos_in_e = jnp.take_along_axis(pos, ek[:, None], axis=1)[:, 0]
    keep = pos_in_e < capacity
    safe_pos = jnp.where(keep, pos_in_e, capacity - 1)

    # slot -> token table; dropped slots point at token 0 but are masked
    disp = jnp.zeros((e, capacity), jnp.int32)
    disp = disp.at[ek, safe_pos].set(
        jnp.where(keep, tok_ids, 0), mode="drop")
    valid = jnp.zeros((e, capacity), bool)
    valid = valid.at[ek, safe_pos].set(keep, mode="drop")

    expert_in = x_flat[disp] * valid[..., None].astype(x.dtype)  # (E,C,D)
    ep_axis = shardctx.get().expert
    if ep_axis is not None:
        expert_in = shardctx.constrain(
            expert_in, jax.sharding.PartitionSpec(ep_axis))

    we = p["experts"]
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in,
                               we["w_gate"].astype(x.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", expert_in, we["w_up"].astype(x.dtype))
    expert_out = jnp.einsum("ecf,efd->ecd", h, we["w_out"].astype(x.dtype))
    if ep_axis is not None:
        expert_out = shardctx.constrain(
            expert_out, jax.sharding.PartitionSpec(ep_axis))

    # combine: per (token, choice) gather back + gate
    out_tk = expert_out[ek, safe_pos]                         # (T*k, D)
    out_tk = out_tk * (gates * keep.astype(x.dtype))[:, None]
    out = out_tk.reshape(t, k, d).sum(axis=1).reshape(b, s, d)

    # switch-style aux (same statistic as the einsum path)
    me = jnp.mean(jax.nn.one_hot(expert_idx, e).sum(1).reshape(b, s, e),
                  axis=(0, 1)) / k
    ce = jnp.mean(probs.reshape(b, s, e), axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    if "shared" in p:
        sh = p["shared"]
        hs = jax.nn.silu(x @ sh["w_gate"].astype(x.dtype)) * (
            x @ sh["w_up"].astype(x.dtype))
        out = out + hs @ sh["w_out"].astype(x.dtype)
    return out, m.router_aux_coef * aux
