from repro.models.transformer import (  # noqa: F401
    forward,
    init_cache,
    init_params,
    loss_fn,
)
