"""Feed-forward blocks: SwiGLU / GeGLU / GELU / squared-ReLU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import dense_init, zeros


def is_gated(act: str) -> bool:
    return act in ("swiglu", "geglu")


def init_mlp(key, d_model, d_ff, act, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"w_out": dense_init(ks[2], (d_ff, d_model), dtype)}
    if is_gated(act):
        p["w_gate"] = dense_init(ks[0], (d_model, d_ff), dtype)
        p["w_up"] = dense_init(ks[1], (d_model, d_ff), dtype)
    else:
        p["w_up"] = dense_init(ks[1], (d_model, d_ff), dtype)
        p["b_up"] = zeros((d_ff,), dtype)
        p["b_out"] = zeros((d_model,), dtype)
    return p


def _act(h, act):
    if act in ("swiglu",):
        return jax.nn.silu(h)
    if act in ("geglu", "gelu"):
        return jax.nn.gelu(h)
    if act == "sqrelu":
        return jnp.square(jax.nn.relu(h))
    raise ValueError(act)


def mlp(p, x, act):
    if is_gated(act):
        h = _act(x @ p["w_gate"].astype(x.dtype), act) * (
            x @ p["w_up"].astype(x.dtype))
        return h @ p["w_out"].astype(x.dtype)
    h = _act(x @ p["w_up"].astype(x.dtype) + p["b_up"].astype(x.dtype), act)
    return h @ p["w_out"].astype(x.dtype) + p["b_out"].astype(x.dtype)
