"""RWKV6 "Finch" block — data-dependent decay WKV recurrence + token shift.
[arXiv:2404.05892]

TPU adaptation (DESIGN.md §3): the reference CUDA wkv6 kernel runs one thread
per channel serially over time; here the recurrence is evaluated in *chunked
matmul form* (intra-chunk causal matmuls on the MXU, inter-chunk lax.scan
carry), mirroring the mamba2 SSD treatment. A Pallas kernel of the chunk body
lives in repro.kernels.wkv6.

Per head (state S is (P_k, P_v), P = head_size):
    o_t = r_t · (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
with per-channel data-dependent decay w_t = exp(-exp(wlog_t)) ∈ (0,1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.norms import groupnorm_heads
from repro.models.params import dense_init, zeros

MIX_STREAMS = 5   # r, k, v, w, g


def init_rwkv6(key, cfg):
    d = cfg.d_model
    lo = cfg.ssm.decay_lora
    ks = jax.random.split(key, 12)
    h, p = cfg.num_heads, cfg.head_dim
    return {
        # token-shift ddlerp
        "mu_base": zeros((d,)),
        "mu": zeros((MIX_STREAMS, d)),
        "lora_w1": dense_init(ks[0], (d, MIX_STREAMS * 32), scale=0.01),
        "lora_w2": dense_init(ks[1], (MIX_STREAMS, 32, d), scale=0.01),
        # projections
        "w_r": dense_init(ks[2], (d, h * p)),
        "w_k": dense_init(ks[3], (d, h * p)),
        "w_v": dense_init(ks[4], (d, h * p)),
        "w_g": dense_init(ks[5], (d, h * p)),
        # data-dependent decay lora + per-channel bonus
        "decay_base": jnp.full((h * p,), -0.6),
        "decay_w1": dense_init(ks[6], (d, lo), scale=0.01),
        "decay_w2": dense_init(ks[7], (lo, h * p), scale=0.01),
        "bonus_u": dense_init(ks[8], (h, p), scale=0.3),
        # output
        "ln_scale": jnp.ones((h * p,)),
        "ln_bias": zeros((h * p,)),
        "w_o": dense_init(ks[9], (h * p, d)),
    }


def init_rwkv6_channel_mix(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": zeros((d,)),
        "mu_r": zeros((d,)),
        "w_k": dense_init(ks[0], (d, f)),
        "w_v": dense_init(ks[1], (f, d)),
        "w_r": dense_init(ks[2], (d, d)),
    }


def _token_shift(x, last):
    """last (B, D) = x_{-1} from previous segment. Returns shifted x."""
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def _ddlerp(p, x, xx):
    """RWKV6 data-dependent lerp -> the 5 mixed streams (B,S,5,D)."""
    delta = xx - x
    base = x + delta * p["mu_base"].astype(x.dtype)
    b, s, d = x.shape
    lora = jnp.tanh(base @ p["lora_w1"].astype(x.dtype))
    lora = lora.reshape(b, s, MIX_STREAMS, -1)
    lora = jnp.einsum("bsml,mld->bsmd", lora, p["lora_w2"].astype(x.dtype))
    mix = p["mu"].astype(x.dtype)[None, None] + lora
    return x[:, :, None] + delta[:, :, None] * mix


def wkv6_chunked(r, k, v, wlog, u, chunk, s0):
    """Chunked WKV6. r/k/v (B,S,H,P); wlog (B,S,H,P) = log decay (negative);
    u (H,P); s0 (B,H,P,P). Returns (o (B,S,H,P), s_end). fp32 math.

    Within a chunk, with cumulative log-decay L_t = sum_{j<=t} wlog_j:
      o_t = (r_t ⊙ e^{L_{t-1}}) S_0 + Σ_{j<t} [(r_t ⊙ e^{L_{t-1}-L_j})·k_j] v_j
            + (r_t·(u ⊙ k_t)) v_t
      S_c = diag(e^{L_c}) S_0 + Σ_j (e^{L_c-L_j} ⊙ k_j)^T v_j
    """
    b, s, h, p = r.shape
    s_orig = s
    pad = (-s) % chunk
    if pad:
        # zero-pad r/k/v and force log-decay 0 on padded steps: the state is
        # neither updated (k=0) nor decayed (w=1) past the true length.
        r, k, v = (jnp.pad(t, [(0, 0), (0, pad), (0, 0), (0, 0)])
                   for t in (r, k, v))
        wlog = jnp.pad(wlog, [(0, 0), (0, pad), (0, 0), (0, 0)])
        s = s + pad
    nc = s // chunk
    f32 = jnp.float32
    r, k, v, wlog = (t.astype(f32) for t in (r, k, v, wlog))
    u = u.astype(f32)

    def chunked(t):
        return jnp.moveaxis(t.reshape(b, nc, chunk, h, p), 1, 0)

    rc, kc, vc, wc = map(chunked, (r, k, v, wlog))

    def body(s_in, inp):
        rk, kk, vk, wk = inp                       # (B,chunk,H,P)
        L = jnp.cumsum(wk, axis=1)                 # inclusive
        Lprev = L - wk                             # L_{t-1}
        r_dec = rk * jnp.exp(Lprev)                # query decayed to chunk 0

        # state contribution
        o = jnp.einsum("bthp,bhpq->bthq", r_dec, s_in)
        # intra-chunk, strictly causal (j < t). The pairwise per-channel
        # decay exp(L_{t-1} - L_j) is <= 1 for j < t, so — unlike the
        # factored r*e^{L} @ k*e^{-L} form — it cannot overflow fp32 under
        # strong decay. Clip masks the (t<=j) upper triangle pre-exp.
        # min(.,0) (not clip): for j<t the diff is already <= 0; the upper
        # bound only guards exp overflow in the masked j>=t triangle. exp
        # underflow needs no lower clamp, and minimum has a cheaper VJP
        # (one select vs clip's two) — this tensor is the §Perf hot spot.
        pair = jnp.exp(jnp.minimum(Lprev[:, :, None] - L[:, None], 0.0))
        att = jnp.einsum("bthp,btjhp,bjhp->bhtj", rk, pair, kk)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        att = jnp.where(causal[None, None], att, 0.0)
        o = o + jnp.einsum("bhtj,bjhq->bthq", att, vk)
        # diagonal bonus term
        diag = jnp.einsum("bthp,hp,bthp->bth", rk, u, kk)
        o = o + diag[..., None] * vk

        l_end = L[:, -1]                           # (B,H,P)
        s_out = (jnp.exp(l_end)[..., None] * s_in
                 + jnp.einsum("bjhp,bjhq->bhpq", kk * jnp.exp(
                     l_end[:, None] - L), vk))
        return s_out, o

    # checkpoint the chunk body: the (chunk,chunk,P) pairwise-decay tensor
    # is recomputed in backward instead of being stacked as a per-chunk
    # residual — without this, backward residuals cost O(S·chunk·H·P) HBM
    # per layer (the dominant §Perf memory term for rwkv6 training).
    s_end, os_ = jax.lax.scan(jax.checkpoint(body), s0.astype(f32),
                              (rc, kc, vc, wc))
    o = jnp.moveaxis(os_, 0, 1).reshape(b, s, h, p)[:, :s_orig]
    return o, s_end


def rwkv6_time_mix(p, x, cfg, *, cache=None):
    """x (B,S,D). cache {"shift": (B,D), "wkv": (B,H,P,P)} or None.
    Returns (out, new_cache)."""
    b, s, d = x.shape
    h, pd = cfg.num_heads, cfg.head_dim
    last = cache["shift"].astype(x.dtype) if cache is not None else \
        jnp.zeros((b, d), x.dtype)
    xx = _token_shift(x, last)
    xr, xk, xv, xw, xg = [t[:, :, 0] for t in jnp.split(
        _ddlerp(p, x, xx), MIX_STREAMS, axis=2)]

    r = (xr @ p["w_r"].astype(x.dtype)).reshape(b, s, h, pd)
    k = (xk @ p["w_k"].astype(x.dtype)).reshape(b, s, h, pd)
    v = (xv @ p["w_v"].astype(x.dtype)).reshape(b, s, h, pd)
    g = xg @ p["w_g"].astype(x.dtype)

    wraw = p["decay_base"].astype(jnp.float32) + (
        jnp.tanh(xw.astype(jnp.float32) @ p["decay_w1"].astype(jnp.float32))
        @ p["decay_w2"].astype(jnp.float32))
    wlog = -jnp.exp(wraw).reshape(b, s, h, pd)      # log decay, < 0

    s0 = (cache["wkv"].astype(jnp.float32) if cache is not None
          else jnp.zeros((b, h, pd, pd), jnp.float32))

    if s == 1 and cache is not None:
        r1, k1, v1 = r[:, 0], k[:, 0], v[:, 0]
        o = (jnp.einsum("bhp,bhpq->bhq", r1.astype(jnp.float32), s0)
             + jnp.einsum("bhp,hp,bhp,bhq->bhq",
                          r1.astype(jnp.float32), p["bonus_u"].astype(
                              jnp.float32),
                          k1.astype(jnp.float32), v1.astype(jnp.float32)))
        s_end = (jnp.exp(wlog[:, 0])[..., None] * s0
                 + jnp.einsum("bhp,bhq->bhpq", k1.astype(jnp.float32),
                              v1.astype(jnp.float32)))
        o = o[:, None]
    elif cfg.use_pallas:
        # differentiable kernel path: the wkv6 custom VJP routes grads
        # through the reverse-chunk Pallas backward; chunk resolves from
        # cfg.ssm inside the ops dispatch layer (VMEM pairwise tile bound)
        from repro.kernels.ops import wkv6 as wkv6_op
        o, s_end = wkv6_op(r, k, v, wlog, p["bonus_u"], s0, cfg=cfg)
    else:
        chunk = min(cfg.ssm.chunk_size, s)
        o, s_end = wkv6_chunked(r, k, v, wlog, p["bonus_u"], chunk, s0)

    o = groupnorm_heads(o.astype(x.dtype), p["ln_scale"], p["ln_bias"],
                        cfg.norm_eps)
    o = (o.reshape(b, s, h * pd) * jax.nn.silu(g))
    out = o @ p["w_o"].astype(x.dtype)

    new_cache = None
    if cache is not None:
        new_cache = {"shift": x[:, -1].astype(cache["shift"].dtype),
                     "wkv": s_end.astype(cache["wkv"].dtype)}
    return out, new_cache


def rwkv6_channel_mix(p, x, cfg, *, cache=None):
    """cache {"shift": (B,D)}. Returns (out, new_cache)."""
    b, s, d = x.shape
    last = cache["shift"].astype(x.dtype) if cache is not None else \
        jnp.zeros((b, d), x.dtype)
    xx = _token_shift(x, last)
    delta = xx - x
    xk = x + delta * p["mu_k"].astype(x.dtype)
    xr = x + delta * p["mu_r"].astype(x.dtype)
    hidden = jnp.square(jax.nn.relu(xk @ p["w_k"].astype(x.dtype)))
    out = jax.nn.sigmoid(xr @ p["w_r"].astype(x.dtype)) * (
        hidden @ p["w_v"].astype(x.dtype))
    new_cache = None
    if cache is not None:
        new_cache = {"shift": x[:, -1].astype(cache["shift"].dtype)}
    return out, new_cache


def init_rwkv6_cache(cfg, batch, dtype=jnp.float32):
    h, pd, d = cfg.num_heads, cfg.head_dim, cfg.d_model
    return {
        "att_shift": jnp.zeros((batch, d), dtype),
        "ffn_shift": jnp.zeros((batch, d), dtype),
        "wkv": jnp.zeros((batch, h, pd, pd), dtype),
    }
