"""Generic multi-architecture transformer: init / forward / cache / loss.

One scan-over-layers decoder/encoder covering all assigned architectures:
  dense (qwen2.5, glm4, chatglm3, gemma3-windowed), moe (granite, deepseek
  MLA+shared-expert+MTP), vlm (qwen2-vl M-RoPE, stubbed vision frontend),
  audio (hubert encoder-only, stubbed conv frontend), hybrid (zamba2
  mamba2+shared-attn groups), ssm (rwkv6), vit (the paper's ViT-B/16).

Layer parameters are stacked along a leading L axis and consumed by
``jax.lax.scan`` — essential to keep HLO size and compile time tractable at
512 devices (DESIGN.md §5).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.models import mamba2 as m2
from repro.models import rwkv6 as rk
from repro.models.attention import attention_block, init_attention, init_mla, \
    mla_block
from repro.models.mlp import init_mlp, mlp
from repro.models.moe import init_moe, moe_ffn, moe_ffn_gather
from repro.models.norms import layernorm, rmsnorm
from repro.models.params import dense_init, embed_init, stack_layer_params, \
    zeros

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def _norm_kind(cfg) -> str:
    return "ln" if cfg.arch_type in ("audio", "vit") else "rms"


def _init_norm(cfg):
    d = cfg.d_model
    p = {"scale": jnp.ones((d,))}
    if _norm_kind(cfg) == "ln":
        p["bias"] = zeros((d,))
    return p


def _apply_norm(cfg, p, x):
    if _norm_kind(cfg) == "ln":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    # use_pallas routes through the fused kernel (differentiable: row-tiled
    # Pallas backward); block_rows resolves from cfg
    return rmsnorm(x, p["scale"], cfg.norm_eps, use_pallas=cfg.use_pallas,
                   block_rows=cfg.norm_block_rows)


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------

def _init_attn_layer(cfg, use_moe):
    def init_one(key):
        ks = jax.random.split(key, 2)
        p = {"ln1": _init_norm(cfg), "ln2": _init_norm(cfg)}
        if cfg.block_kind == "mla":
            p["attn"] = init_mla(ks[0], cfg)
        else:
            p["attn"] = init_attention(ks[0], cfg)
        if use_moe:
            p["moe"] = init_moe(ks[1], cfg)
        else:
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act)
        return p
    return init_one


def _init_rwkv_layer(cfg):
    def init_one(key):
        ks = jax.random.split(key, 2)
        return {
            "ln1": _init_norm(cfg), "ln2": _init_norm(cfg),
            "time_mix": rk.init_rwkv6(ks[0], cfg),
            "channel_mix": rk.init_rwkv6_channel_mix(ks[1], cfg),
        }
    return init_one


def _init_mamba_layer(cfg):
    def init_one(key):
        return {"ln": _init_norm(cfg), "mamba": m2.init_mamba2(key, cfg)}
    return init_one


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def init_params(cfg, key):
    keys = jax.random.split(key, 8)
    params = {}

    # ---- embeddings ----
    if cfg.arch_type == "vit":
        n_patch = (cfg.image_size // cfg.patch_size) ** 2
        params["embed"] = {
            "patch_w": dense_init(
                keys[0], (cfg.patch_size * cfg.patch_size * 3, cfg.d_model)),
            "patch_b": zeros((cfg.d_model,)),
            "cls": zeros((1, 1, cfg.d_model)),
            "pos": embed_init(keys[5], (n_patch + 1, cfg.d_model)),
        }
    elif cfg.arch_type == "audio":
        params["embed"] = {
            "feat_proj": dense_init(keys[0], (cfg.audio_feat_dim,
                                              cfg.d_model)),
            "feat_b": zeros((cfg.d_model,)),
            "mask_emb": embed_init(keys[5], (cfg.d_model,)),
        }
    else:
        params["embed"] = {"tok": embed_init(keys[0], (cfg.vocab_size,
                                                       cfg.d_model))}

    # ---- blocks ----
    moe_cfg = cfg.moe
    if cfg.block_kind in ("attn", "mla"):
        if moe_cfg and moe_cfg.num_experts > 0:
            nd = moe_cfg.first_dense_layers
            if nd > 0:
                params["dense_stack"] = stack_layer_params(
                    _init_attn_layer(cfg, use_moe=False), nd, keys[1])
            params["moe_stack"] = stack_layer_params(
                _init_attn_layer(cfg, use_moe=True),
                cfg.num_layers - nd, keys[2])
        else:
            params["stack"] = stack_layer_params(
                _init_attn_layer(cfg, use_moe=False), cfg.num_layers, keys[1])
    elif cfg.block_kind == "rwkv6":
        params["stack"] = stack_layer_params(
            _init_rwkv_layer(cfg), cfg.num_layers, keys[1])
    elif cfg.block_kind == "mamba2":
        params["stack"] = stack_layer_params(
            _init_mamba_layer(cfg), cfg.num_layers, keys[1])
        if cfg.hybrid_group > 0:
            # zamba2: ONE weight-shared attention(+mlp) block
            shared = _init_attn_layer(cfg, use_moe=False)(keys[2])
            params["shared_attn"] = shared
    else:
        raise ValueError(cfg.block_kind)

    # ---- head ----
    params["final_norm"] = _init_norm(cfg)
    if cfg.arch_type == "vit":
        params["head"] = {"w": dense_init(keys[3], (cfg.d_model,
                                                    cfg.num_classes)),
                          "b": zeros((cfg.num_classes,))}
    elif not cfg.tie_embeddings:
        params["head"] = {"w": dense_init(keys[3], (cfg.d_model,
                                                    cfg.vocab_size))}

    # ---- MTP (deepseek-v3) ----
    if cfg.mtp_depth > 0:
        params["mtp"] = {
            "proj": dense_init(keys[4], (2 * cfg.d_model, cfg.d_model)),
            "block": _init_attn_layer(cfg, use_moe=False)(keys[6]),
            "norm_h": _init_norm(cfg), "norm_e": _init_norm(cfg),
            "final_norm": _init_norm(cfg),
        }
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _attn_layer_cache(cfg, batch, max_len, dtype):
    if cfg.block_kind == "mla":
        m = cfg.mla
        return {"c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
                "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim),
                                    dtype)}
    return {"k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                           dtype),
            "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                           dtype)}


def init_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    """Concrete zero cache. Use jax.eval_shape(...) for dry-run specs."""
    def stack(fn, n):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *([fn()] * n)) \
            if n > 1 else jax.tree.map(lambda x: x[None], fn())

    if cfg.block_kind in ("attn", "mla"):
        per = lambda: _attn_layer_cache(cfg, batch, max_len, dtype)  # noqa
        if cfg.moe and cfg.moe.num_experts > 0:
            nd = cfg.moe.first_dense_layers
            out = {"moe": stack(per, cfg.num_layers - nd)}
            if nd > 0:
                out["dense"] = stack(per, nd)
            return out
        return {"layers": stack(per, cfg.num_layers)}
    if cfg.block_kind == "rwkv6":
        per = lambda: rk.init_rwkv6_cache(cfg, batch, dtype)  # noqa
        return {"layers": stack(per, cfg.num_layers)}
    if cfg.block_kind == "mamba2":
        per = lambda: m2.init_mamba2_cache(cfg, batch, dtype)  # noqa
        out = {"mamba": stack(per, cfg.num_layers)}
        if cfg.hybrid_group > 0:
            ngroups = cfg.num_layers // cfg.hybrid_group
            pa = lambda: _attn_layer_cache(cfg, batch, max_len, dtype)  # noqa
            out["attn"] = stack(pa, ngroups)
        return out
    raise ValueError(cfg.block_kind)


# ---------------------------------------------------------------------------
# layer stacks (scan)
# ---------------------------------------------------------------------------

def _attn_mlp_body(cfg, use_moe, h, lp, window, positions, layer_cache,
                   cache_index):
    a_in = _apply_norm(cfg, lp["ln1"], h)
    if cfg.block_kind == "mla":
        attn_out, new_c = mla_block(lp["attn"], a_in, cfg,
                                    positions=positions, cache=layer_cache,
                                    cache_index=cache_index)
    else:
        attn_out, new_c = attention_block(lp["attn"], a_in, cfg,
                                          positions=positions, window=window,
                                          cache=layer_cache,
                                          cache_index=cache_index)
    h = h + attn_out
    m_in = _apply_norm(cfg, lp["ln2"], h)
    if use_moe:
        moe_fn = moe_ffn_gather if cfg.moe_impl == "gather" else moe_ffn
        ff, aux = moe_fn(lp["moe"], m_in, cfg)
    else:
        ff, aux = mlp(lp["mlp"], m_in, cfg.act), jnp.float32(0.0)
    return h + ff, new_c, aux


def _remat(cfg, fn):
    if cfg.remat == "block":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def _run_attn_stack(cfg, stack, h, positions, windows, cache, cache_index,
                    use_moe):
    """scan over stacked layers; cache may be None."""
    has_cache = cache is not None

    def body(carry, xs):
        lp, window, layer_cache = xs if has_cache else (xs[0], xs[1], None)
        hh = carry
        hh, new_c, aux = _attn_mlp_body(cfg, use_moe, hh, lp, window,
                                        positions, layer_cache, cache_index)
        return hh, (new_c, aux) if has_cache else aux

    body_fn = _remat(cfg, body)
    xs = (stack, windows, cache) if has_cache else (stack, windows)
    h, ys = jax.lax.scan(body_fn, h, xs)
    if has_cache:
        new_cache, auxs = ys
    else:
        new_cache, auxs = None, ys
    return h, new_cache, jnp.sum(auxs)


def _run_rwkv_stack(cfg, stack, h, cache):
    has_cache = cache is not None

    def body(carry, xs):
        lp, layer_cache = xs if has_cache else (xs, None)
        hh = carry
        tc = {"shift": layer_cache["att_shift"], "wkv": layer_cache["wkv"]} \
            if has_cache else None
        out, new_tc = rk.rwkv6_time_mix(
            lp["time_mix"], _apply_norm(cfg, lp["ln1"], hh), cfg, cache=tc)
        hh = hh + out
        cc = {"shift": layer_cache["ffn_shift"]} if has_cache else None
        out, new_cc = rk.rwkv6_channel_mix(
            lp["channel_mix"], _apply_norm(cfg, lp["ln2"], hh), cfg, cache=cc)
        hh = hh + out
        new_c = {"att_shift": new_tc["shift"], "wkv": new_tc["wkv"],
                 "ffn_shift": new_cc["shift"]} if has_cache else None
        return hh, new_c if has_cache else jnp.float32(0.0)

    body_fn = _remat(cfg, body)
    xs = (stack, cache) if has_cache else stack
    h, ys = jax.lax.scan(body_fn, h, xs)
    return h, (ys if has_cache else None), jnp.float32(0.0)


def _run_mamba_stack(cfg, stack, h, cache):
    has_cache = cache is not None

    def body(carry, xs):
        lp, lc = xs if has_cache else (xs, None)
        out, new_lc = m2.mamba2_block(
            lp["mamba"], _apply_norm(cfg, lp["ln"], carry), cfg, cache=lc)
        return carry + out, (new_lc if has_cache else jnp.float32(0.0))

    body_fn = _remat(cfg, body)
    xs = (stack, cache["mamba"]) if has_cache else stack
    h, ys = jax.lax.scan(body_fn, h, xs)
    return h, ({"mamba": ys} if has_cache else None), jnp.float32(0.0)


def _run_zamba_stack(cfg, params, h, positions, cache, cache_index):
    """Outer scan over groups of (hybrid_group mamba layers + shared attn)."""
    g = cfg.hybrid_group
    ngroups = cfg.num_layers // g
    has_cache = cache is not None
    shared = params["shared_attn"]

    group_fn = functools.partial(_group_body, cfg=cfg, shared=shared,
                                 positions=positions, cache_index=cache_index,
                                 has_cache=has_cache, g=g)
    # reshape stacked mamba params (L, ...) -> (ngroups, g, ...)
    mstack = jax.tree.map(
        lambda x: x.reshape((ngroups, g) + x.shape[1:]), params["stack"])
    if has_cache:
        mcache = jax.tree.map(
            lambda x: x.reshape((ngroups, g) + x.shape[1:]), cache["mamba"])
        xs = (mstack, mcache, cache["attn"])
    else:
        xs = (mstack,)
    body = _remat(cfg, group_fn)
    h, ys = jax.lax.scan(body, h, xs)
    if has_cache:
        new_m, new_a = ys
        new_cache = {"mamba": jax.tree.map(
            lambda x: x.reshape((ngroups * g,) + x.shape[2:]), new_m),
            "attn": new_a}
    else:
        new_cache = None
    return h, new_cache, jnp.float32(0.0)


def _group_body(carry, xs, *, cfg, shared, positions, cache_index, has_cache,
                g):
    h = carry
    if has_cache:
        mparams, mcache, acache = xs
    else:
        (mparams,), mcache, acache = xs, None, None

    def inner(hh, inner_xs):
        lp, lc = inner_xs if has_cache else (inner_xs, None)
        out, new_lc = m2.mamba2_block(
            lp["mamba"], _apply_norm(cfg, lp["ln"], hh), cfg, cache=lc)
        return hh + out, new_lc if has_cache else jnp.float32(0.0)

    h, inner_ys = jax.lax.scan(inner, h,
                               (mparams, mcache) if has_cache else mparams)
    new_mcache = inner_ys if has_cache else None

    h, new_acache, _ = _attn_mlp_body(
        cfg, False, h, shared, jnp.int32(0), positions, acache, cache_index)
    if has_cache:
        return h, (new_mcache, new_acache)
    return h, jnp.float32(0.0)


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------

def _sinusoidal_pos(s, d, offset=0):
    pos = jnp.arange(offset, offset + s, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32)
                  * (-math.log(10000.0) / d))
    pe = jnp.zeros((s, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def _embed(cfg, params, batch, mode):
    """Returns h (B,S,D) in cfg.dtype and rope positions."""
    dtype = jnp.dtype(cfg.dtype)
    e = params["embed"]
    if cfg.arch_type == "vit":
        img = batch["images"]                       # (B, H, W, 3)
        b = img.shape[0]
        ps = cfg.patch_size
        n = cfg.image_size // ps
        patches = img.reshape(b, n, ps, n, ps, 3).transpose(0, 1, 3, 2, 4, 5)
        patches = patches.reshape(b, n * n, ps * ps * 3).astype(dtype)
        h = patches @ e["patch_w"].astype(dtype) + e["patch_b"].astype(dtype)
        cls = jnp.broadcast_to(e["cls"].astype(dtype), (b, 1, cfg.d_model))
        h = jnp.concatenate([cls, h], axis=1)
        h = h + e["pos"].astype(dtype)[None]
        return h, None
    if cfg.arch_type == "audio":
        feats = batch["features"].astype(dtype)     # (B, S, F)
        h = feats @ e["feat_proj"].astype(dtype) + e["feat_b"].astype(dtype)
        if "mask" in batch:                         # masked prediction
            h = jnp.where(batch["mask"][..., None],
                          e["mask_emb"].astype(dtype)[None, None], h)
        # conv-positional frontend is stubbed -> sinusoidal absolute
        h = h + _sinusoidal_pos(h.shape[1], cfg.d_model).astype(dtype)[None]
        return h, None

    tokens = batch["tokens"] if mode != "decode" else batch["token"]
    h = params["embed"]["tok"][tokens].astype(dtype)
    if cfg.embed_scale:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), dtype)
    if cfg.arch_type == "vlm" and mode != "decode" and \
            "image_embeds" in batch:
        n_img = batch["image_embeds"].shape[1]
        h = jnp.concatenate([batch["image_embeds"].astype(dtype),
                             h[:, n_img:]], axis=1)
    # rope positions
    b, s = h.shape[:2]
    if mode == "decode":
        idx = batch["index"]                        # scalar int32
        if cfg.rope_style == "mrope":
            positions = jnp.broadcast_to(idx, (b, 1, 3)).astype(jnp.int32)
        else:
            positions = jnp.broadcast_to(idx, (b, 1)).astype(jnp.int32)
    elif cfg.rope_style == "mrope":
        positions = batch.get("positions")
        if positions is None:
            base = jnp.arange(s, dtype=jnp.int32)[None, :, None]
            positions = jnp.broadcast_to(base, (b, s, 3))
    else:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
    return h, positions


def _head(cfg, params, h):
    h = _apply_norm(cfg, params["final_norm"], h)
    if cfg.arch_type == "vit":
        cls = h[:, 0]
        return cls @ params["head"]["w"].astype(h.dtype) + \
            params["head"]["b"].astype(h.dtype)
    if cfg.tie_embeddings:
        return h @ params["embed"]["tok"].T.astype(h.dtype)
    return h @ params["head"]["w"].astype(h.dtype)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def forward(cfg, params, batch, *, mode="train", cache=None):
    """Returns (logits, new_cache, aux) — aux: {"moe_aux", "mtp_logits"}.

    mode: "train" (no cache) | "prefill" (fills cache) | "decode" (one token,
    batch = {"token": (B,1), "index": scalar}).
    """
    assert mode in ("train", "prefill", "decode"), mode
    if mode != "train":
        assert cache is not None or mode == "prefill", mode
    h, positions = _embed(cfg, params, batch, mode)
    cache_index = batch.get("index", jnp.int32(0)) if mode == "decode" \
        else jnp.int32(0)
    aux = {"moe_aux": jnp.float32(0.0)}

    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)

    if cfg.block_kind in ("attn", "mla"):
        layers_cache = cache
        if cfg.moe and cfg.moe.num_experts > 0:
            nd = cfg.moe.first_dense_layers
            new_cache = {}
            if nd > 0:
                h, nc, _ = _run_attn_stack(
                    cfg, params["dense_stack"], h, positions, windows[:nd],
                    cache["dense"] if cache else None, cache_index,
                    use_moe=False)
                if cache:
                    new_cache["dense"] = nc
            h, nc, moe_aux = _run_attn_stack(
                cfg, params["moe_stack"], h, positions, windows[nd:],
                cache["moe"] if cache else None, cache_index, use_moe=True)
            if cache:
                new_cache["moe"] = nc
            else:
                new_cache = None
            aux["moe_aux"] = moe_aux
        else:
            h, nc, _ = _run_attn_stack(
                cfg, params["stack"], h, positions, windows,
                cache["layers"] if cache else None, cache_index,
                use_moe=False)
            new_cache = {"layers": nc} if cache else None
    elif cfg.block_kind == "rwkv6":
        h, nc, _ = _run_rwkv_stack(cfg, params["stack"], h,
                                   cache["layers"] if cache else None)
        new_cache = {"layers": nc} if cache else None
    elif cfg.block_kind == "mamba2":
        if cfg.hybrid_group > 0:
            h, new_cache, _ = _run_zamba_stack(cfg, params, h, positions,
                                               cache, cache_index)
        else:
            h, new_cache, _ = _run_mamba_stack(cfg, params["stack"], h,
                                               cache)
    else:
        raise ValueError(cfg.block_kind)

    logits = _head(cfg, params, h)

    # ---- MTP auxiliary head (DeepSeek-V3), train mode only ----
    if cfg.mtp_depth > 0 and mode == "train" and cfg.arch_type != "vit":
        mp = params["mtp"]
        tok = batch["tokens"]
        nxt = jnp.concatenate([tok[:, 1:], tok[:, -1:]], axis=1)
        e_next = params["embed"]["tok"][nxt].astype(h.dtype)
        mtp_in = jnp.concatenate([
            _apply_norm(cfg, mp["norm_h"], h),
            _apply_norm(cfg, mp["norm_e"], e_next)], axis=-1)
        mh = mtp_in @ mp["proj"].astype(h.dtype)
        mh, _, _ = _attn_mlp_body(cfg, False, mh, mp["block"], jnp.int32(0),
                                  positions, None, jnp.int32(0))
        mtp_logits = _apply_norm(cfg, mp["final_norm"], mh) @ (
            params["embed"]["tok"].T.astype(h.dtype)
            if cfg.tie_embeddings or "head" not in params
            else params["head"]["w"].astype(h.dtype))
        aux["mtp_logits"] = mtp_logits

    return logits, new_cache, aux


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def _xent(logits, labels, mask=None):
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _soft_xent(logits, labels, *, smoothing=0.0):
    """Cross-entropy against a soft (B, C) target distribution — the
    Mixup/CutMix label path — with optional uniform label smoothing
    ``y <- (1 - eps) * y + eps / C``. Hard int labels are accepted and
    one-hotted (the smoothing-only case)."""
    logits = logits.astype(jnp.float32)
    num_classes = logits.shape[-1]
    if labels.ndim == logits.ndim - 1:
        y = jax.nn.one_hot(labels, num_classes, dtype=jnp.float32)
    else:
        y = labels.astype(jnp.float32)
    if smoothing:
        y = y * (1.0 - smoothing) + smoothing / num_classes
    logp = logits - jax.nn.logsumexp(logits, axis=-1, keepdims=True)
    return jnp.mean(-jnp.sum(y * logp, axis=-1))


def classification_counts(logits, labels, mask=None, *, topk=5):
    """Integer correctness counts + fp32 NLL sum for classification eval.

    Counts — not means — are the cross-layout reduction unit: summing
    per-example {0, 1} indicators as integers is exact under ANY dp/pipe
    sharding (integer addition is associative), so eval accuracy is
    bitwise layout-invariant. ``mask`` (B,) zeroes padded tail examples of
    the final non-divisible eval batch. Loss is reported as an fp32 sum of
    per-example NLL (un-smoothed — eval loss stays recipe-independent);
    the caller divides by the total count.
    """
    logits = logits.astype(jnp.float32)
    if mask is None:
        mask = jnp.ones(labels.shape[:1], jnp.float32)
    maski = mask.astype(jnp.int32)
    pred = jnp.argmax(logits, axis=-1)
    k = min(topk, logits.shape[-1])
    _, topi = jax.lax.top_k(logits, k)
    in_topk = jnp.any(topi == labels[:, None], axis=-1)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[..., 0]
    return {
        "top1": jnp.sum((pred == labels).astype(jnp.int32) * maski),
        "top5": jnp.sum(in_topk.astype(jnp.int32) * maski),
        "count": jnp.sum(maski),
        "loss_sum": jnp.sum((lse - gold) * mask.astype(jnp.float32)),
    }


def loss_from_logits(cfg, logits, batch, aux=None):
    """Loss + metrics given final-head ``logits`` for ``batch``.

    Shared by ``loss_fn`` (single forward) and ``core/pipeline.py`` (which
    produces per-microbatch logits on the last pipeline stage) so both paths
    compute byte-identical objectives.
    """
    if aux is None:
        aux = {"moe_aux": jnp.float32(0.0)}
    metrics = {}
    if cfg.arch_type == "vit":
        labels = batch["labels"]
        soft = labels.ndim == 2         # Mixup/CutMix soft-label batches
        if soft or cfg.label_smoothing > 0.0:
            loss = _soft_xent(logits, labels, smoothing=cfg.label_smoothing)
        else:
            loss = _xent(logits, labels)
        hard = jnp.argmax(labels, -1) if soft else labels
        metrics["acc"] = jnp.mean(
            (jnp.argmax(logits, -1) == hard).astype(jnp.float32))
    elif cfg.arch_type == "audio":
        loss = _xent(logits, batch["labels"], batch["mask"])
    else:
        tok = batch["tokens"]
        mask = jnp.ones(tok.shape, bool).at[:, -1].set(False)
        if cfg.arch_type == "vlm" and "image_embeds" in batch:
            n_img = batch["image_embeds"].shape[1]
            mask &= jnp.arange(tok.shape[1])[None] >= n_img
        labels = jnp.concatenate([tok[:, 1:], tok[:, -1:]], axis=1)
        loss = _xent(logits, labels, mask)
        if "mtp_logits" in aux:
            l2 = jnp.concatenate([labels[:, 1:], labels[:, -1:]], axis=1)
            m2_ = mask.at[:, -2:].set(False)
            loss = loss + 0.3 * _xent(aux["mtp_logits"], l2, m2_)
    loss = loss + aux["moe_aux"]
    metrics["moe_aux"] = aux["moe_aux"]
    metrics["loss"] = loss
    return loss, metrics


def loss_fn(cfg, params, batch, *, rng=None):
    """Scalar training loss + metrics dict, per architecture family."""
    logits, _, aux = forward(cfg, params, batch, mode="train")
    return loss_from_logits(cfg, logits, batch, aux)


# ---------------------------------------------------------------------------
# pipeline-parallel building blocks (core/pipeline.py)
# ---------------------------------------------------------------------------

def embed(cfg, params, batch, mode="train"):
    """Public embedding entry: (h, rope positions) — pipeline stage 0."""
    return _embed(cfg, params, batch, mode)


def apply_head(cfg, params, h):
    """Final norm + classification/LM head — pipeline last stage."""
    return _head(cfg, params, h)


def stack_forward(cfg, stack, h, positions, windows):
    """Run a contiguous slice of stacked attn/mla layers (train mode, no
    cache) — the per-stage compute unit for pipeline parallelism. ``stack``
    leaves carry a leading (layers-in-slice,) axis; ``windows`` matches."""
    h, _, _ = _run_attn_stack(cfg, stack, h, positions, windows, None,
                              jnp.int32(0), use_moe=False)
    return h
