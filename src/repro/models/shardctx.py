"""Activation-sharding context.

The engine/launcher installs PartitionSpec hints here; model code applies them
via ``with_sharding_constraint`` when running under a mesh. This is how
DeepSpeed-Ulysses sequence parallelism is expressed TPU-natively: activations
constrained to sequence-sharded before attention, head-sharded inside it —
GSPMD lowers the respecting reshard to the same all_to_all pair the paper's
reference (arXiv:2309.14509) issues explicitly.

Models never import repro.core, so this lives under models/.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Optional

import jax


@dataclass
class ShardHints:
    # (B, S, D) activations between blocks
    act: Optional[jax.sharding.PartitionSpec] = None
    # (B, S, H, hd) queries INSIDE attention
    attn_q: Optional[jax.sharding.PartitionSpec] = None
    # (B, T, KH, hd) keys/values INSIDE attention — may differ from attn_q
    # when num_kv_heads doesn't divide the model axis (GQA kv=2/8 on a
    # 16-way axis): padded shardings caused per-k-block all-gather storms
    attn_kv: Optional[jax.sharding.PartitionSpec] = None
    # (B, S, H, hd) attention output
    attn_seq: Optional[jax.sharding.PartitionSpec] = None
    # (E, ...) expert-parallel leading axis for MoE intermediate tensors
    expert: Optional[str] = None   # mesh axis name for expert parallelism


_HINTS = ShardHints()


def get() -> ShardHints:
    return _HINTS


@contextlib.contextmanager
def use(hints: ShardHints):
    global _HINTS
    prev, _HINTS = _HINTS, hints
    try:
        yield
    finally:
        _HINTS = prev


def constrain(x, spec):
    """with_sharding_constraint if a spec is installed, else identity."""
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        # no mesh context (single-device tests): hints are advisory
        return x
