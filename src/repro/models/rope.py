"""Rotary position embeddings: full, half (GLM 2d), M-RoPE (Qwen2-VL)."""
from __future__ import annotations

import jax.numpy as jnp


def _rope_angles(positions, dim, theta):
    """positions (..., ) -> angles (..., dim//2) in float32."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return positions[..., None].astype(jnp.float32) * inv_freq


def _rotate(x, cos, sin):
    """Rotate-half convention. x (..., d); cos/sin (..., d//2)."""
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def mrope_angles(positions, dim, theta, sections):
    """M-RoPE: positions (B, S, 3) = (t, h, w) grids; each frequency band is
    assigned to one section. Returns angles (B, S, dim//2)."""
    n = dim // 2
    t, h, w = sections
    assert t + h + w == n, (sections, n)
    sec_ids = jnp.concatenate([
        jnp.zeros((t,), jnp.int32), jnp.ones((h,), jnp.int32),
        2 * jnp.ones((w,), jnp.int32)])
    pos = positions.astype(jnp.float32)[..., sec_ids]          # (B, S, n)
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return pos * inv_freq


def apply_rope(q, k, positions, *, style, theta, sections=(0, 0, 0)):
    """q (B,S,H,hd), k (B,T,KH,hd). positions: (B,S) int32 or (B,S,3) for
    mrope. q and k must share position arrays of matching leading shape —
    pass (q_pos, k_pos) tuple when they differ (decode)."""
    if style == "none":
        return q, k
    q_pos, k_pos = positions if isinstance(positions, tuple) else (positions,
                                                                   positions)
    hd = q.shape[-1]
    if style == "mrope":
        ang_q = mrope_angles(q_pos, hd, theta, sections)
        ang_k = mrope_angles(k_pos, hd, theta, sections)
        cos_q, sin_q = jnp.cos(ang_q)[:, :, None], jnp.sin(ang_q)[:, :, None]
        cos_k, sin_k = jnp.cos(ang_k)[:, :, None], jnp.sin(ang_k)[:, :, None]
        return (_rotate(q, cos_q, sin_q).astype(q.dtype),
                _rotate(k, cos_k, sin_k).astype(k.dtype))

    rot_dim = hd if style == "full" else hd // 2
    ang_q = _rope_angles(q_pos, rot_dim, theta)[:, :, None]   # (B,S,1,rd/2)
    ang_k = _rope_angles(k_pos, rot_dim, theta)[:, :, None]

    def _apply(x, ang):
        cos, sin = jnp.cos(ang), jnp.sin(ang)
        if rot_dim == hd:
            return _rotate(x, cos, sin)
        head, tail = x[..., :rot_dim], x[..., rot_dim:]
        return jnp.concatenate([_rotate(head, cos, sin), tail], -1)

    return _apply(q, ang_q).astype(q.dtype), _apply(k, ang_k).astype(k.dtype)


def apply_rope_1d(x, positions, *, theta):
    """RoPE for a single (B,S,1,rd) stream (MLA shared rope-key)."""
    ang = _rope_angles(positions, x.shape[-1], theta)[:, :, None]
    return _rotate(x, jnp.cos(ang), jnp.sin(ang)).astype(x.dtype)
