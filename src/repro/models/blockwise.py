"""Blockwise (flash) attention in pure XLA with a custom VJP.

The Pallas kernel (repro.kernels.flash_attention) is the TPU-target fast
path, but Mosaic cannot compile on this CPU container — and the multi-pod
dry-run must ``.lower().compile()`` every pair here. This module is the
XLA-lowerable equivalent: online-softmax over K/V blocks via lax.scan
(forward), and the standard flash backward (recompute P from the saved LSE,
blockwise dq/dk/dv) — so the compiled HLO has flash-like O(S·bk) working
sets instead of the naive O(S·T) score materialization, and the dry-run's
memory/roofline numbers reflect the deployable configuration.

Layouts match models/attention.py: q (B,S,H,D), k/v (B,T,KH,Dv), GQA folded
internally. Mask semantics: causal + sliding window (0 = full) + bidir.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -2.0 ** 30


def _mask_block(q0, k0, bq, bk, *, causal, window):
    """q0/k0 may be traced scalars (absolute offsets of the tiles)."""
    qp = q0 + jnp.arange(bq)[:, None]
    kp = k0 + jnp.arange(bk)[None, :]
    ok = jnp.ones((bq, bk), bool)
    if causal:
        ok &= kp <= qp
    ok &= jnp.where(window > 0, (qp - kp) < window, True)
    return ok


def _fwd_scan(q, k, v, *, causal, window, block_k, q_offset=0):
    """q (B,S,KH,G,D) pre-scaled; k (B,T,KH,D), v (B,T,KH,Dv).
    Returns out (B,S,KH,G,Dv), lse (B,S,KH,G)."""
    b, s, kh, g, d = q.shape
    t = k.shape[1]
    dv = v.shape[-1]
    nk = t // block_k
    kb = jnp.moveaxis(k.reshape(b, nk, block_k, kh, d), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nk, block_k, kh, dv), 1, 0)

    def body(carry, xs):
        m, l, acc = carry
        kblk, vblk, ki = xs
        scores = jnp.einsum("bskgd,btkd->bkgst", q, kblk,
                            preferred_element_type=jnp.float32)
        mb = _mask_block(q_offset, ki * block_k, s, block_k, causal=causal,
                         window=window)
        scores = jnp.where(mb[None, None, None], scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(v.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kh, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kh, g, s), jnp.float32)
    a0 = jnp.zeros((b, kh, g, s, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kb, vb, jnp.arange(nk)))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return (jnp.moveaxis(out, 3, 1),                      # (B,S,KH,G,Dv)
            jnp.moveaxis(lse, 3, 1))                      # (B,S,KH,G)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def blockwise_attention(q, k, v, window=0, q_offset=0, causal=True,
                        block_k=512):
    """q (B,S,H,D), k (B,T,KH,D), v (B,T,KH,Dv) -> (B,S,H,Dv).
    `window` and `q_offset` may be traced int scalars (scan values);
    window 0 = full; q_offset = absolute position of q[0] (q-chunking)."""
    return _bw_fwd(q, k, v, window, q_offset, causal, block_k)[0]


def _prep(q, k, block_k):
    b, s, h, d = q.shape
    kh, t = k.shape[2], k.shape[1]
    g = h // kh
    bk = min(block_k, t)
    while t % bk:
        bk -= 1
    scale = d ** -0.5
    # keep the MXU dot inputs in the model dtype (bf16 on TPU): f32 dots run
    # at 1/4 MXU rate and double the HBM traffic; accumulation stays f32
    # via preferred_element_type
    qg = (q * jnp.asarray(scale, q.dtype)).reshape(b, s, kh, g, d)
    return qg, bk


def _bw_fwd(q, k, v, window, q_offset, causal, block_k):
    qg, bk = _prep(q, k, block_k)
    out, lse = _fwd_scan(qg, k, v, causal=causal, window=window, block_k=bk,
                         q_offset=q_offset)
    b, s, kh, g, dv = out.shape
    o = out.reshape(b, s, kh * g, dv).astype(q.dtype)
    return o, (q, k, v, o, lse, window, q_offset)


def _bw_bwd(causal, block_k, res, do):
    q, k, v, o, lse, window, q_offset = res
    qg, bk = _prep(q, k, block_k)              # (B,S,KH,G,D) scaled fp32
    b, s, kh, g, d = qg.shape
    t = k.shape[1]
    dv = v.shape[-1]
    nk = t // bk
    scale = d ** -0.5

    do_f = do.astype(jnp.float32).reshape(b, s, kh, g, dv)
    o_f = o.astype(jnp.float32).reshape(b, s, kh, g, dv)
    delta = jnp.sum(do_f * o_f, axis=-1)       # (B,S,KH,G)

    kb = jnp.moveaxis(k.reshape(b, nk, bk, kh, d), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nk, bk, kh, dv), 1, 0)

    def body(dq_acc, xs):
        kblk, vblk, ki = xs
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, kblk,
                            preferred_element_type=jnp.float32)
        mb = _mask_block(q_offset, ki * bk, s, bk, causal=causal,
                         window=window)
        scores = jnp.where(mb[None, None, None], scores, NEG_INF)
        p = jnp.exp(scores - jnp.moveaxis(lse, 1, 3)[..., None])  # (bkgst)
        dv_blk = jnp.einsum("bkgst,bskgd->btkd", p, do_f)
        dp = jnp.einsum("bskgd,btkd->bkgst", do_f, vblk,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - jnp.moveaxis(delta, 1, 3)[..., None])
        dq_blk = jnp.einsum("bkgst,btkd->bskgd", ds, kblk,
                            preferred_element_type=jnp.float32)
        dk_blk = jnp.einsum("bkgst,bskgd->btkd", ds, qg)
        return dq_acc + dq_blk, (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, s, kh, g, d), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (kb, vb, jnp.arange(nk)))
    dq = (dq * scale).reshape(b, s, kh * g, d).astype(q.dtype)
    dk = jnp.moveaxis(dks, 0, 1).reshape(b, t, kh, d).astype(k.dtype)
    dvv = jnp.moveaxis(dvs, 0, 1).reshape(b, t, kh, dv).astype(v.dtype)
    dwin = np.zeros(np.shape(window), dtype=jax.dtypes.float0)
    dqo = np.zeros(np.shape(q_offset), dtype=jax.dtypes.float0)
    return dq, dk, dvv, dwin, dqo


blockwise_attention.defvjp(_bw_fwd, _bw_bwd)


def blockwise_attention_qchunked(q, k, v, window=0, causal=True,
                                 block_k=512, block_q=512):
    """q-chunked wrapper: scans blockwise_attention over q tiles so the
    flash accumulator carried across k-blocks is (bq x Dv) rather than
    (S x Dv) — this is what keeps the XLA-lowered emulation's HBM traffic
    (and therefore the dry-run memory roofline term) at flash levels.
    Gradients flow through the scan (dk/dv accumulate across q tiles)."""
    b, s, h, d = q.shape
    bq = min(block_q, s)
    while s % bq:
        bq -= 1
    nq = s // bq
    if nq == 1:
        return blockwise_attention(q, k, v, window, 0, causal, block_k)
    qt = jnp.moveaxis(q.reshape(b, nq, bq, h, d), 1, 0)

    def body(_, xs):
        qi, qblk = xs
        o = blockwise_attention(qblk, k, v, window, qi * bq, causal,
                                block_k)
        return None, o

    _, outs = jax.lax.scan(body, None, (jnp.arange(nq), qt))
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, h, v.shape[-1])
