"""Attention: MHA/GQA (+bias, sliding window, softcap, M-RoPE) and
DeepSeek-style MLA with latent KV cache (absorbed decode path).

All functions operate on (B, S, H, hd) tensors; per-layer params are plain
dicts so they stack along a leading L axis for scan-over-layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import shardctx
from repro.models.norms import rmsnorm
from repro.models.params import dense_init, zeros
from repro.models.rope import apply_rope, apply_rope_1d

NEG_INF = -2.0 ** 30   # finite: keeps fully-masked rows NaN-free


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attention(key, cfg):
    d, h, kh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd)),
        "wk": dense_init(ks[1], (d, kh * hd)),
        "wv": dense_init(ks[2], (d, kh * hd)),
        "wo": dense_init(ks[3], (h * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros((h * hd,))
        p["bk"] = zeros((kh * hd,))
        p["bv"] = zeros((kh * hd,))
    return p


def init_mla(key, cfg):
    m, d, h = cfg.mla, cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "w_dq": dense_init(ks[0], (d, m.q_lora_rank)),
        "q_norm": jnp.ones((m.q_lora_rank,)),
        "w_uq": dense_init(ks[1], (m.q_lora_rank, h * qk)),
        "w_dkv": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim)),
        "kv_norm": jnp.ones((m.kv_lora_rank,)),
        "w_uk": dense_init(ks[3], (m.kv_lora_rank, h * m.qk_nope_head_dim)),
        "w_uv": dense_init(ks[4], (m.kv_lora_rank, h * m.v_head_dim)),
        "wo": dense_init(ks[5], (h * m.v_head_dim, d)),
    }


# ---------------------------------------------------------------------------
# scaled dot-product attention (GQA-grouped, fp32 softmax)
# ---------------------------------------------------------------------------

def _mask(q_pos, k_pos, *, causal, window, kv_len_valid=None):
    """Boolean (.., S, T) mask. ``window`` may be a traced scalar (0=full)."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        ok &= kp <= qp
    win_ok = jnp.where(window > 0, (qp - kp) < window, True)
    ok &= win_ok
    if kv_len_valid is not None:
        ok &= kp < kv_len_valid
    return ok


def sdpa(q, k, v, mask, *, softcap=0.0):
    """q (B,S,H,hd), k/v (B,T,KH,hd), mask broadcastable to (B,1,1,S,T).
    GQA grouping is internal. fp32 accumulation."""
    b, s, h, hd = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, hd)
    scale = hd ** -0.5
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    if mask is not None:
        scores = jnp.where(mask[:, None, None] if mask.ndim == 3
                           else mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, s, h, v.shape[-1])    # v head dim may differ (MLA)


# ---------------------------------------------------------------------------
# full GQA attention block (project → rope → sdpa → out)
# ---------------------------------------------------------------------------

def attention_block(p, x, cfg, *, positions, window, cache=None,
                    cache_index=None, layer_slot=None):
    """Returns (out, new_layer_cache).

    cache (for this layer): {"k": (B, Smax, KH, hd), "v": ...} or None.
    cache_index: traced scalar — current length (decode) / 0 (prefill).
    """
    b, s, _ = x.shape
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    hints = shardctx.get()

    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kh, hd)
    v = v.reshape(b, s, kh, hd)

    if cfg.rope_style != "none":
        q, k = apply_rope(q, k, positions, style=cfg.rope_style,
                          theta=cfg.rope_theta, sections=cfg.mrope_sections)

    new_cache = None
    if cache is not None:
        # decode writes one slot at cache_index; prefill writes the block at
        # position 0 (the causal mask hides the unwritten tail).
        idx = cache_index if s == 1 else 0
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
        new_cache = {"k": kc, "v": vc}
        k, v = kc, vc

    # TP/Ulysses resharding (no-op unless hints installed; decode layouts
    # come from the cache shardings instead)
    if s > 1:
        q = shardctx.constrain(q, hints.attn_q)
        k = shardctx.constrain(k, hints.attn_kv)
        v = shardctx.constrain(v, hints.attn_kv)

    t = k.shape[1]
    if cache is not None and s == 1:
        # decode: query sits at `cache_index`; valid keys are <= it, within
        # the sliding window when one is set.
        k_pos = jnp.arange(t)
        mask = k_pos <= cache_index
        mask &= jnp.where(window > 0, (cache_index - k_pos) < window, True)
        mask = mask[None, None, None, None]                # (1,1,1,1,T)
        out = sdpa(q, k, v, mask, softcap=cfg.attn_logit_softcap)
    elif cfg.use_pallas and cfg.attn_logit_softcap == 0.0:
        # flash kernel: causal/window masks are positional -> in-kernel;
        # train gradients route through the kernel's custom VJP (Pallas
        # backward passes), so this is the differentiable hot path. Block
        # sizes resolve from cfg inside the ops dispatch layer.
        from repro.kernels.ops import flash_mha
        out = flash_mha(q, k, v, causal=cfg.causal, window=window, cfg=cfg)
    elif cfg.attn_impl == "blockwise" and cfg.attn_logit_softcap == 0.0:
        from repro.models.blockwise import blockwise_attention_qchunked
        out = blockwise_attention_qchunked(q, k, v, window,
                                           causal=cfg.causal,
                                           block_k=cfg.attn_block_k,
                                           block_q=cfg.attn_block_q)
    else:
        q_pos = jnp.arange(s)[None]
        k_pos = jnp.arange(t)[None]
        mask = _mask(q_pos, k_pos, causal=cfg.causal,
                     window=window)[:, None, None]         # (1,1,1,S,T)
        out = sdpa(q, k, v, mask, softcap=cfg.attn_logit_softcap)
    if s > 1:
        out = shardctx.constrain(out, hints.attn_seq)
    out = out.reshape(b, s, h * hd) @ p["wo"].astype(x.dtype)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3): latent-compressed KV, absorbed decode
# ---------------------------------------------------------------------------

def mla_block(p, x, cfg, *, positions, cache=None, cache_index=None):
    """Returns (out, new_layer_cache). Cache stores the COMPRESSED latent
    c_kv (B, Smax, kv_lora) + shared rope key (B, Smax, rope_dim) — the MLA
    memory saving (vs. per-head K/V) is num_heads*(nope+v)/(kv_lora+rope)
    ≈ 128*256/576 ≈ 57x."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    nope, rope_d, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    cq = rmsnorm(x @ p["w_dq"].astype(x.dtype), p["q_norm"], cfg.norm_eps,
                 use_pallas=cfg.use_pallas, block_rows=cfg.norm_block_rows)
    q = (cq @ p["w_uq"].astype(x.dtype)).reshape(b, s, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    dkv = x @ p["w_dkv"].astype(x.dtype)
    c_kv = rmsnorm(dkv[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps,
                   use_pallas=cfg.use_pallas, block_rows=cfg.norm_block_rows)
    k_rope = dkv[..., m.kv_lora_rank:][:, :, None]         # (B,S,1,rope)

    q_rope, _ = apply_rope(q_rope, q_rope, positions, style="full",
                           theta=cfg.rope_theta)
    k_rope = apply_rope_1d(k_rope, positions, theta=cfg.rope_theta)[:, :, 0]

    new_cache = None
    if cache is not None:
        idx = cache_index if s == 1 else 0
        ckv_c = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, idx, 0))
        kr_c = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
            (0, idx, 0))
        new_cache = {"c_kv": ckv_c, "k_rope": kr_c}
        c_kv, k_rope = ckv_c, kr_c

    scale = (nope + rope_d) ** -0.5
    t = c_kv.shape[1]

    if cache is not None and s == 1:
        # ---- absorbed decode: never materialize per-head K/V ----
        w_uk = p["w_uk"].astype(x.dtype).reshape(m.kv_lora_rank, h, nope)
        q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)   # (B,1,H,kv_lora)
        scores = (jnp.einsum("bshr,btr->bhst", q_lat, c_kv,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bshd,btd->bhst", q_rope, k_rope,
                               preferred_element_type=jnp.float32)) * scale
        valid = jnp.arange(t)[None, None, None, :] <= cache_index
        scores = jnp.where(valid, scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        ctx = jnp.einsum("bhst,btr->bshr", w, c_kv)          # (B,1,H,kv_lora)
        w_uv = p["w_uv"].astype(x.dtype).reshape(m.kv_lora_rank, h, vd)
        out = jnp.einsum("bshr,rhd->bshd", ctx, w_uv)
    else:
        # ---- train/prefill: materialize K/V from latent ----
        k_nope = (c_kv @ p["w_uk"].astype(x.dtype)).reshape(b, t, h, nope)
        v = (c_kv @ p["w_uv"].astype(x.dtype)).reshape(b, t, h, vd)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                      (b, t, h, rope_d))], -1)
        qfull = jnp.concatenate([q_nope, q_rope], -1)
        if cfg.use_pallas:
            from repro.kernels.ops import flash_mha
            out = flash_mha(qfull, k, v, causal=True, window=0, cfg=cfg)
        elif cfg.attn_impl == "blockwise":
            from repro.models.blockwise import blockwise_attention_qchunked
            out = blockwise_attention_qchunked(qfull, k, v, 0, causal=True,
                                               block_k=cfg.attn_block_k,
                                               block_q=cfg.attn_block_q)
        else:
            q_pos = jnp.arange(s)[None]
            k_pos = jnp.arange(t)[None]
            mask = _mask(q_pos, k_pos, causal=True, window=0)[:, None, None]
            out = sdpa(qfull, k, v, mask)

    out = out.reshape(b, s, h * vd) @ p["wo"].astype(x.dtype)
    return out, new_cache
