"""Mamba2 (SSD) block — chunked matmul-form scan, TPU/MXU-adapted.

The CUDA Mamba2 kernel is a warp-specialized selective scan; the TPU-native
adaptation (per DESIGN.md §3) is the *chunked SSD* form: within a chunk the
recurrence is a causal-masked matmul (MXU work), across chunks a short
``lax.scan`` carries the (H, P, N) state. Chunk size = cfg.ssm.chunk_size.

Recurrence (per head h, state (P, N)):
    h_t = a_t * h_{t-1} + dt_t * x_t B_t^T ;    y_t = h_t C_t + D * x_t
with a_t = exp(dt_t * A), A = -exp(A_log), dt_t = softplus(dt_raw + bias).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.norms import rmsnorm
from repro.models.params import dense_init, zeros


def dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.state_dim
    return d_in, nheads, conv_ch


def init_mamba2(key, cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_in, nheads, conv_ch = dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        # order: [z | x | B | C | dt]
        "w_in": dense_init(ks[0], (d, 2 * d_in + 2 * s.state_dim + nheads)),
        "conv_w": dense_init(ks[1], (s.conv_kernel, conv_ch), scale=0.3),
        "conv_b": zeros((conv_ch,)),
        "A_log": jnp.zeros((nheads,)),            # A = -exp(0) = -1
        "dt_bias": jnp.full((nheads,), 0.5),
        "D": jnp.ones((nheads,)),
        "norm": jnp.ones((d_in,)),
        "w_out": dense_init(ks[3], (d_in, d)),
    }


def _split_proj(cfg, proj):
    s = cfg.ssm
    d_in, nheads, _ = dims(cfg)
    n = s.state_dim
    z = proj[..., :d_in]
    xbc = proj[..., d_in: 2 * d_in + 2 * n]
    dt = proj[..., 2 * d_in + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv1d. xbc (B,S,C); conv_state (B,k-1,C) or None.
    Returns (out (B,S,C), new_state (B,k-1,C))."""
    k = conv_w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros(xbc.shape[:1] + (k - 1, xbc.shape[-1]),
                               xbc.dtype)
    full = jnp.concatenate([conv_state, xbc], axis=1)        # (B,k-1+S,C)
    out = sum(full[:, i:i + xbc.shape[1]] * conv_w[i].astype(xbc.dtype)
              for i in range(k))
    out = jax.nn.silu(out + conv_b.astype(xbc.dtype))
    new_state = full[:, -(k - 1):]
    return out, new_state


def _ssd_chunk_scan(xh, bmat, cmat, dt, a_log, chunk, h0):
    """Chunked SSD.

    xh (B,S,H,P) head inputs; bmat/cmat (B,S,N); dt (B,S,H) post-softplus;
    h0 (B,H,P,N) initial state. Returns (y (B,S,H,P), h_end).
    All math fp32.
    """
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    s_orig = s
    pad = (-s) % chunk
    if pad:
        # zero-pad: dtx=0 leaves the state untouched, and padded log-decay is
        # forced to 0 below so the carried state is not spuriously decayed.
        xh, bmat, cmat, dt = (jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] *
                                      (t.ndim - 2)) for t in
                              (xh, bmat, cmat, dt))
        s = s + pad
    nc = s // chunk

    f32 = jnp.float32
    xh, bmat, cmat, dt = (t.astype(f32) for t in (xh, bmat, cmat, dt))
    A = -jnp.exp(a_log.astype(f32))                          # (H,)
    la = dt * A                                              # log a_t (B,S,H)
    if pad:
        valid = (jnp.arange(s) < s_orig)[None, :, None]
        la = jnp.where(valid, la, 0.0)
        dt = jnp.where(valid, dt, 0.0)

    def chunked(t, trail):
        return t.reshape((b, nc, chunk) + trail)

    xh_c = chunked(xh, (h, p))
    b_c = chunked(bmat, (n,))
    c_c = chunked(cmat, (n,))
    dt_c = chunked(dt, (h,))
    la_c = chunked(la, (h,))

    # move chunk axis to front for scan: (nc, B, chunk, ...)
    xh_c, b_c, c_c, dt_c, la_c = (
        jnp.moveaxis(t, 1, 0) for t in (xh_c, b_c, c_c, dt_c, la_c))

    def body(h_in, inp):
        xk, bk, ck, dtk, lak = inp                # (B,chunk,...)
        L = jnp.cumsum(lak, axis=1)               # (B,chunk,H) inclusive
        dtx = xk * dtk[..., None]                 # (B,chunk,H,P)

        # intra-chunk: M[i,j] = (C_i·B_j) exp(L_i - L_j) [j<=i]
        cb = jnp.einsum("bin,bjn->bij", ck, bk)   # (B,chunk,chunk)
        decay = jnp.exp(L[:, :, None, :] - L[:, None, :, :])   # (B,i,j,H)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        m = jnp.where(causal[None, :, :, None], cb[..., None] * decay, 0.0)
        y = jnp.einsum("bijh,bjhp->bihp", m, dtx)

        # contribution of incoming state
        y = y + jnp.einsum("bin,bhpn,bih->bihp", ck, h_in, jnp.exp(L))

        # state update: h_out = exp(L_end) h_in + sum_j exp(L_end-L_j) dtx B^T
        l_end = L[:, -1]                          # (B,H)
        w = jnp.exp(l_end[:, None] - L)           # (B,chunk,H)
        h_out = (jnp.exp(l_end)[..., None, None] * h_in
                 + jnp.einsum("bjh,bjhp,bjn->bhpn", w, dtx, bk))
        return h_out, y

    # checkpoint: recompute the (chunk,chunk) decay/causal tensors in
    # backward rather than saving them per chunk (see rwkv6 note)
    h_end, ys = jax.lax.scan(jax.checkpoint(body), h0.astype(f32),
                             (xh_c, b_c, c_c, dt_c, la_c))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)[:, :s_orig]
    return y, h_end


def mamba2_block(p, x, cfg, *, cache=None):
    """x (B,S,D). cache: {"conv": (B,k-1,C), "ssd": (B,H,P,N)} or None.
    Returns (out (B,S,D), new_cache)."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    d_in, nheads, conv_ch = dims(cfg)
    n, pdim = s_cfg.state_dim, s_cfg.head_dim

    proj = x @ p["w_in"].astype(x.dtype)
    z, xbc, dt_raw = _split_proj(cfg, proj)

    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)

    xc = xbc[..., :d_in].reshape(b, s, nheads, pdim)
    bmat = xbc[..., d_in:d_in + n]
    cmat = xbc[..., d_in + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))

    h0 = (cache["ssd"].astype(jnp.float32) if cache is not None
          else jnp.zeros((b, nheads, pdim, n), jnp.float32))

    if s == 1 and cache is not None:
        # decode: one recurrence step, no chunking
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        a = jnp.exp(dt[:, 0] * A)                             # (B,H)
        dtx = (xc[:, 0].astype(jnp.float32) * dt[:, 0, :, None])
        h_end = (a[..., None, None] * h0
                 + jnp.einsum("bhp,bn->bhpn", dtx, bmat[:, 0].astype(
                     jnp.float32)))
        y = jnp.einsum("bhpn,bn->bhp", h_end, cmat[:, 0].astype(jnp.float32))
        y = y[:, None]
    else:
        y, h_end = _ssd_chunk_scan(xc, bmat, cmat, dt, p["A_log"],
                                   min(s_cfg.chunk_size, s), h0)

    y = y + p["D"].astype(jnp.float32)[:, None] * xc.astype(jnp.float32)
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"], cfg.norm_eps, use_pallas=cfg.use_pallas,
                block_rows=cfg.norm_block_rows)
    out = y @ p["w_out"].astype(x.dtype)

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "ssd": h_end.astype(cache["ssd"].dtype)}
    return out, new_cache


def init_mamba2_cache(cfg, batch, dtype=jnp.float32):
    s = cfg.ssm
    d_in, nheads, conv_ch = dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_kernel - 1, conv_ch), dtype),
        "ssd": jnp.zeros((batch, nheads, s.head_dim, s.state_dim), dtype),
    }
