"""RMSNorm / LayerNorm / per-head GroupNorm.

``rmsnorm`` is the dispatch point for the fused Pallas kernel: callers pass
``use_pallas=cfg.use_pallas`` (and optionally ``block_rows`` /
``interpret``) and the differentiable ``kernels.ops.fused_rmsnorm`` — with
its row-tiled Pallas backward — takes over the 2·L-per-step hot path;
otherwise the pure-jnp form below runs (fp32 math either way)."""
from __future__ import annotations

import jax.numpy as jnp


def rmsnorm(x, scale, eps, *, use_pallas=False, block_rows=None,
            interpret=None):
    if use_pallas:
        from repro.kernels.ops import fused_rmsnorm
        return fused_rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                             interpret=interpret)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf / jnp.sqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, scale, bias, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) / jnp.sqrt(var + eps)
    return (out * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def groupnorm_heads(x, scale, bias, eps):
    """Per-head group norm, x (B, S, H, P), scale/bias (H*P,)."""
    b, s, h, p = x.shape
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = ((xf - mu) / jnp.sqrt(var + eps)).reshape(b, s, h * p)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.reshape(b, s, h, p).astype(x.dtype)
