"""RMSNorm / LayerNorm / per-head GroupNorm (pure-jnp; the Pallas variant in
repro.kernels.rmsnorm is swapped in when cfg.use_pallas)."""
from __future__ import annotations

import jax.numpy as jnp


def rmsnorm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf / jnp.sqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, scale, bias, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) / jnp.sqrt(var + eps)
    return (out * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def groupnorm_heads(x, scale, bias, eps):
    """Per-head group norm, x (B, S, H, P), scale/bias (H*P,)."""
    b, s, h, p = x.shape
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = ((xf - mu) / jnp.sqrt(var + eps)).reshape(b, s, h * p)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.reshape(b, s, h, p).astype(x.dtype)
