"""Parameter initialization helpers + analytic parameter counting."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, shape, dtype=jnp.float32, scale=None):
    """Truncated-normal fan-in init (what ViT/LLM stacks actually use)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return 0.02 * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def zeros(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


def stack_layer_params(init_one, num_layers, key):
    """Initialize ``num_layers`` independent copies of a per-layer param tree
    and stack along a leading layer axis (scan-over-layers layout)."""
    keys = jax.random.split(key, num_layers)
    return jax.vmap(init_one)(keys)


def tree_num_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(tree))


def count_params_analytic(cfg, active_only: bool = False) -> int:
    """Parameter count via ``jax.eval_shape`` over the real initializer —
    guaranteed consistent with the model actually built.

    ``active_only``: MoE experts counted at top_k/num_experts utilization
    (the 6·N_active·D convention for MoE MODEL_FLOPS).
    """
    from repro.models.transformer import init_params

    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = tree_num_params(shapes)
    if not active_only or cfg.moe is None or cfg.moe.num_experts == 0:
        return total

    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    routed = sum(int(np.prod(leaf.shape)) for path, leaf in flat
                 if "experts" in jax.tree_util.keystr(path))
    frac = cfg.moe.top_k / cfg.moe.num_experts
    return int(total - routed + routed * frac)
