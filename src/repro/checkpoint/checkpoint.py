"""Elastic sharded checkpointing: shard-local saves + layout-resharding
restore (the DeepSpeed ZeRO-partitioned-checkpoint contract).

Format — one directory per step, committed by atomic rename:

    step_00000010/
      manifest.json        logical metadata + shard index maps
      shards-p00.npz       process 0's unique addressable shards (raw bytes)

Save is **shard-local**: each process iterates its arrays'
``addressable_shards`` and writes only shards with ``replica_id == 0`` —
replicated leaves are written exactly once, ZeRO/pp-sharded leaves
contribute exactly their partition, and nothing is ever gathered across
hosts, so per-process bytes stay at shard size. The manifest records, per
logical leaf: dtype, logical shape, the PartitionSpec it was saved under,
and for every shard its ``[start, stop)`` index ranges plus the owning
device id — enough to reassemble the logical array under ANY target
layout (and to account bytes-per-device; see
``scripts/zero_memory_table.py --ckpt-sizes``).

Restore is **elastic**: logical arrays are reassembled from the shard
index maps and ``device_put`` against the TARGET shardings (the restoring
engine's param/opt specs, including a pipe-sharded stacked-layer L axis),
so a run saved at dp=8 restores into dp=2×pp=2 or dp=4×zero=3 unchanged.
Template mismatches are never tolerated: missing/unexpected leaf paths
raise ``KeyError`` naming them, shape/dtype mismatches raise ``ValueError``
with both sides printed, and incomplete shard coverage raises.

Async saves (:class:`AsyncCheckpointer`) keep checkpoint cadence off the
step critical path: the device→host shard snapshot happens synchronously
(the double buffer — after it returns the live arrays may be donated
away), serialization runs on a background thread, the directory rename is
the commit point, and in-flight saves are bounded with backpressure.

Hardened IO (the resilience layer — ROADMAP "Resilience"):

* the manifest records a **crc32 per shard**; restore verifies every
  shard it reads and raises :class:`CheckpointCorruptError` on mismatch
  (or on an unreadable shard file) instead of silently loading garbage;
* save IO retries transient ``OSError``s with jittered-exponential
  backoff (`repro.resilience.backoff`) — the tmp-dir staging is
  idempotent, so a half-written attempt is simply rebuilt;
* :func:`restore_latest_valid` falls back to the **newest valid earlier
  step** when the latest is torn or corrupt, and :func:`latest_step`
  skips manifest-less and ``*.tmp`` directories instead of tripping;
* :func:`gc_checkpoints` retains the newest ``keep_last_k`` steps but
  NEVER deletes the newest step that verifies — a retention policy
  cannot be allowed to destroy the only restorable state.

Multi-host caveat (single-controller repo): every process would write its
own ``shards-p{NN}.npz`` but the manifest is written by process 0 from its
local shard table; a true multi-host deployment needs a manifest merge
barrier. On this repo's single-process meshes the manifest is complete.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from typing import Optional, Tuple

import jax
import ml_dtypes
import numpy as np

from repro.core import sharding as shd
from repro.resilience import faults as _faults
from repro.resilience.backoff import BackoffPolicy

FORMAT = "repro-elastic-ckpt/v1"

# save-side IO retry: a handful of quick attempts — a checkpoint that
# cannot land within this budget is a real outage, not a blip
DEFAULT_IO_BACKOFF = BackoffPolicy(max_attempts=4, base_delay=0.05,
                                   multiplier=2.0, max_delay=1.0,
                                   jitter=0.5)


class CheckpointCorruptError(ValueError):
    """Checkpoint bytes fail verification (checksum mismatch, unreadable
    shard file, missing manifest) — the restore-fallback trigger."""


def _np_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):           # DictKey / FlattenedIndexKey
            parts.append(str(k.key))
        elif hasattr(k, "name"):        # GetAttrKey (TrainState fields)
            parts.append(str(k.name))
        elif hasattr(k, "idx"):         # SequenceKey (tuples, OptState)
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _flatten(tree) -> list:
    """[(key, leaf)] in tree order (keys are stable across save/restore
    because both sides flatten the same structure)."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(_path_str(path), leaf) for path, leaf in flat]


def _index_ranges(index, shape) -> list:
    out = []
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else int(sl.start)
        stop = int(dim) if sl.stop is None else int(sl.stop)
        out.append([start, stop])
    return out


# ---------------------------------------------------------------------------
# save: snapshot (device -> host, shard-local) then write (host only)
# ---------------------------------------------------------------------------

def _snapshot(tree) -> dict:
    """Host-side copy of every unique addressable shard (replica 0 only) —
    the double buffer an async save serializes from. No cross-device or
    cross-host gather happens here: one ``device_get`` per owned shard."""
    snap = {"mesh": None, "leaves": {}}
    for key, leaf in _flatten(tree):
        if hasattr(leaf, "addressable_shards"):
            # np.array(copy=True), NOT np.asarray: on CPU backends the
            # latter returns a zero-copy VIEW of the live device buffer,
            # which would alias memory the caller is about to donate —
            # the copy is what makes this a double buffer
            shards = [(_index_ranges(sh.index, leaf.shape),
                       np.array(sh.data, copy=True), int(sh.device.id))
                      for sh in leaf.addressable_shards
                      if sh.replica_id == 0]
            desc = shd.describe_sharding(leaf)
            shape, dtype = tuple(leaf.shape), str(np.dtype(leaf.dtype))
        else:                           # host numpy / python scalar leaf
            arr = np.asarray(leaf)
            shards = [([[0, d] for d in arr.shape], arr, 0)]
            desc, shape, dtype = None, arr.shape, str(arr.dtype)
        if desc and desc.get("mesh") and snap["mesh"] is None:
            snap["mesh"] = desc["mesh"]
        snap["leaves"][key] = {
            "dtype": dtype, "shape": list(shape),
            "spec": desc["spec"] if desc else None, "shards": shards}
    return snap


def _write_snapshot(ckpt_dir: str, step: int, snap: dict) -> str:
    """Serialize a snapshot to ``step_{step}``: shard npz + manifest into a
    tmp directory, then atomic rename-on-complete (readers never observe a
    partial checkpoint; ``latest_step`` ignores ``*.tmp``). Idempotent —
    a retried attempt rebuilds the tmp staging dir from scratch."""
    _faults.check("ckpt_write", step)   # chaos harness (no-op in prod)
    proc = jax.process_index()
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    shard_file = f"shards-p{proc:02d}.npz"
    arrays, leaves = {}, {}
    slot = 0
    for key, meta in snap["leaves"].items():
        entries = []
        for ranges, data, dev in meta["shards"]:
            k = f"a{slot}"
            slot += 1
            # raw bytes: npz cannot serialize ml_dtypes (bfloat16 etc.)
            raw = data.tobytes()
            arrays[k] = np.frombuffer(raw, np.uint8)
            entries.append({"file": shard_file, "key": k,
                            "shape": list(data.shape), "index": ranges,
                            "device": dev, "crc32": zlib.crc32(raw)})
        leaves[key] = {"dtype": meta["dtype"], "shape": meta["shape"],
                       "spec": meta["spec"], "shards": entries}
    np.savez(os.path.join(tmp, shard_file), **arrays)
    if proc == 0:
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"format": FORMAT, "step": step,
                       "mesh": snap["mesh"], "leaves": leaves}, f, indent=1)
    if os.path.isdir(final):
        shutil.rmtree(final)            # re-save of the same step
    os.rename(tmp, final)
    _faults.corrupt_committed(final, step)  # chaos harness (no-op in prod)
    return final


def _write_with_retry(ckpt_dir: str, step: int, snap: dict,
                      retry: Optional[BackoffPolicy]) -> str:
    """Write, retrying transient IO failures (OSError) with backoff;
    persistent failures (anything else) propagate immediately."""
    if retry is None:
        return _write_snapshot(ckpt_dir, step, snap)
    return retry.retry(
        lambda: _write_snapshot(ckpt_dir, step, snap),
        retryable=(OSError,),
        on_retry=lambda a, d, e: print(
            f"[ckpt] save step {step} attempt {a + 1} failed ({e}); "
            f"retrying in {d:.2f}s", flush=True))


def save_checkpoint(ckpt_dir: str, step: int, tree, *,
                    retry: Optional[BackoffPolicy] = DEFAULT_IO_BACKOFF,
                    keep_last_k: int = 0) -> str:
    """Synchronous shard-local save. ``tree`` is any pytree of arrays
    (typically a full ``TrainState``). Transient IO errors are retried
    per ``retry``; ``keep_last_k`` > 0 runs retention GC after the
    commit (never deleting the newest verifiable step)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    path = _write_with_retry(ckpt_dir, step, _snapshot(tree), retry)
    if keep_last_k:
        gc_checkpoints(ckpt_dir, keep_last_k)
    return path


class AsyncCheckpointer:
    """Double-buffered async saver with a bounded in-flight count.

    ``save`` snapshots the shards to host memory synchronously (so the
    caller may immediately donate/overwrite the live arrays) and hands
    serialization to a background thread; when ``max_in_flight`` writes are
    already pending it blocks on the oldest — backpressure instead of
    unbounded host-memory growth. ``wait()`` drains and re-raises the first
    background failure; failures also FAIL FAST on the next ``save``
    (both before and after the backpressure wait — a run must not keep
    training for another ``ckpt_every`` steps on top of a save path that
    is already broken).

    Background writes retry transient IO errors with ``retry`` (the
    hardened-IO policy) and run retention GC when ``keep_last_k`` > 0.

    ``close()`` drains WITHOUT raising — the stored failure is logged,
    never swallowed silently — for teardown paths where an exception is
    already in flight; ``__exit__`` closes on an exceptional exit and
    waits (re-raising) on a clean one. ``__del__`` is belt-and-braces
    ``close()``.
    """

    def __init__(self, max_in_flight: int = 2,
                 retry: Optional[BackoffPolicy] = DEFAULT_IO_BACKOFF,
                 keep_last_k: int = 0):
        if max_in_flight < 1:
            raise ValueError(f"max_in_flight must be >= 1: {max_in_flight}")
        self._max = max_in_flight
        self._retry = retry
        self._keep_last_k = keep_last_k
        self._pending: list = []
        self._errors: list = []
        self._lock = threading.Lock()

    def _raise_if_failed(self):
        with self._lock:
            if self._errors:
                err = self._errors[0]
                raise RuntimeError(
                    f"async checkpoint save failed: {err!r}") from err

    def save(self, ckpt_dir: str, step: int, tree) -> str:
        self._raise_if_failed()
        # prune finished writes (long runs would otherwise hold one dead
        # Thread per save), then block on the oldest until under the cap
        while True:
            self._pending = [t for t in self._pending if t.is_alive()]
            if len(self._pending) < self._max:
                break
            self._pending[0].join()
        self._raise_if_failed()
        os.makedirs(ckpt_dir, exist_ok=True)
        snap = _snapshot(tree)          # device -> host, before returning

        def run():
            try:
                _write_with_retry(ckpt_dir, step, snap, self._retry)
                if self._keep_last_k:
                    gc_checkpoints(ckpt_dir, self._keep_last_k)
            except BaseException as e:  # noqa: BLE001 — surfaced in wait()
                with self._lock:
                    self._errors.append(e)

        t = threading.Thread(target=run, name=f"ckpt-save-{step}",
                             daemon=True)
        self._pending.append(t)
        t.start()
        return os.path.join(ckpt_dir, f"step_{step:08d}")

    def wait(self):
        for t in self._pending:
            t.join()
        self._pending.clear()
        self._raise_if_failed()

    def close(self):
        """Drain in-flight saves without raising; a stored background
        failure is LOGGED (never silently discarded) — the teardown
        counterpart of ``wait()`` for already-failing exits."""
        for t in self._pending:
            t.join()
        self._pending.clear()
        with self._lock:
            errors, self._errors = self._errors, []
        for err in errors:
            print(f"[ckpt] WARNING: async checkpoint save failed "
                  f"(surfaced at close): {err!r}", flush=True)

    def __enter__(self):
        return self

    def __exit__(self, exc_type, *exc):
        # on an exceptional exit, don't mask the in-flight exception with
        # a save failure — close() logs it instead
        if exc_type is not None:
            self.close()
        else:
            self.wait()
        return False

    def __del__(self):
        try:
            if self._pending or self._errors:
                self.close()
        except Exception:   # noqa: BLE001 — interpreter-shutdown tolerant
            pass


# ---------------------------------------------------------------------------
# restore: strict template match, reassemble, reshard to target layout
# ---------------------------------------------------------------------------

def restore_checkpoint(ckpt_dir: str, step: int, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs; values ignored), resharding to ``shardings`` when
    given (the TARGET engine's NamedShardings — this is the elastic path).

    Raises ``KeyError`` when the checkpoint and template trees disagree on
    leaf paths, and ``ValueError`` (all offenders listed, both sides
    printed) on any shape/dtype mismatch or incomplete shard coverage.
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest.get("format") != FORMAT:
        raise ValueError(
            f"checkpoint {d} has format {manifest.get('format')!r}; this "
            f"restorer reads {FORMAT!r} — refusing to reinterpret shard "
            f"bytes across format versions")
    leaves_meta = manifest["leaves"]

    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    like_items = [(_path_str(path), leaf) for path, leaf in flat_like]
    like_keys = [k for k, _ in like_items]
    missing = sorted(set(like_keys) - set(leaves_meta))
    unexpected = sorted(set(leaves_meta) - set(like_keys))
    if missing or unexpected:
        raise KeyError(
            f"checkpoint {d} does not match the restore template — "
            f"missing from checkpoint: {missing or '[]'}; "
            f"unexpected in checkpoint: {unexpected or '[]'}")

    errors = []
    for key, leaf in like_items:
        meta = leaves_meta[key]
        want_shape = tuple(getattr(leaf, "shape", np.shape(leaf)))
        want_dtype = np.dtype(getattr(leaf, "dtype",
                                      np.asarray(leaf).dtype))
        got_shape, got_dtype = tuple(meta["shape"]), _np_dtype(meta["dtype"])
        if got_shape != want_shape or got_dtype != want_dtype:
            errors.append(
                f"  {key}: checkpoint shape={got_shape} "
                f"dtype={got_dtype.name} vs template shape={want_shape} "
                f"dtype={want_dtype.name}")
        covered = sum(
            int(np.prod([b - a for a, b in e["index"]]))
            for e in meta["shards"])
        if covered != int(np.prod(got_shape)):
            errors.append(
                f"  {key}: shards cover {covered} of "
                f"{int(np.prod(got_shape))} elements (incomplete or "
                f"overlapping shard map)")
    if errors:
        raise ValueError(
            f"checkpoint {d} incompatible with restore template:\n"
            + "\n".join(errors))

    npz_cache: dict = {}
    out_leaves = []
    for key, _ in like_items:
        meta = leaves_meta[key]
        dtype = _np_dtype(meta["dtype"])
        out = np.zeros(tuple(meta["shape"]), dtype)
        for e in meta["shards"]:
            raw = _read_shard_bytes(d, e, npz_cache, context=key)
            sub = np.frombuffer(raw, dtype).reshape(e["shape"])
            out[tuple(slice(a, b) for a, b in e["index"])] = sub
        out_leaves.append(out)
    tree = jax.tree_util.tree_unflatten(treedef, out_leaves)
    if shardings is not None:
        # the elastic step: place each logical array against the TARGET
        # layout's sharding — GSPMD-free resharding via device_put
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree


def _read_shard_bytes(d: str, entry: dict, npz_cache: dict, *,
                      context: str) -> bytes:
    """One shard's raw bytes, checksum-verified against the manifest.
    Unreadable files (torn zip, IO error) and crc mismatches both raise
    :class:`CheckpointCorruptError` — the fallback-restore trigger."""
    try:
        if entry["file"] not in npz_cache:
            npz_cache[entry["file"]] = np.load(
                os.path.join(d, entry["file"]))
        raw = npz_cache[entry["file"]][entry["key"]].tobytes()
    except Exception as e:  # noqa: BLE001 — any read failure = corrupt
        raise CheckpointCorruptError(
            f"checkpoint {d}: shard file {entry['file']!r} "
            f"(leaf {context}, key {entry['key']}) unreadable: "
            f"{e!r}") from e
    if "crc32" in entry and zlib.crc32(raw) != entry["crc32"]:
        raise CheckpointCorruptError(
            f"checkpoint {d}: shard {entry['key']} of leaf {context} "
            f"fails crc32 verification (manifest {entry['crc32']}, "
            f"bytes {zlib.crc32(raw)}) — torn or corrupt write")
    return raw


def verify_checkpoint(ckpt_dir: str, step: int) -> None:
    """Full integrity check of one step: manifest present with the right
    format, every shard file readable, every per-shard crc32 matching.
    Raises :class:`CheckpointCorruptError` (or ``FileNotFoundError`` for
    a missing manifest); returns None when the checkpoint is sound.
    Pre-checksum (v1 manifests without ``crc32``) checkpoints pass on
    readability alone."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest_path = os.path.join(d, "manifest.json")
    try:
        with open(manifest_path) as f:
            manifest = json.load(f)
    except FileNotFoundError:
        raise
    except Exception as e:  # noqa: BLE001 — torn manifest = corrupt
        raise CheckpointCorruptError(
            f"checkpoint {d}: manifest unreadable: {e!r}") from e
    if manifest.get("format") != FORMAT:
        raise CheckpointCorruptError(
            f"checkpoint {d}: format {manifest.get('format')!r} != "
            f"{FORMAT!r}")
    npz_cache: dict = {}
    for key, meta in manifest["leaves"].items():
        for e in meta["shards"]:
            _read_shard_bytes(d, e, npz_cache, context=key)


def list_steps(ckpt_dir: str) -> list:
    """All committed step numbers, ascending. A step counts only when
    its ``manifest.json`` exists — ``*.tmp`` staging dirs (never renamed
    in) and manifest-less torn directories are skipped, not tripped on."""
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(
        int(m.group(1)) for name in os.listdir(ckpt_dir)
        if (m := re.match(r"step_(\d+)$", name))
        and os.path.isfile(os.path.join(ckpt_dir, name, "manifest.json")))


def latest_step(ckpt_dir: str) -> int:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else -1


def latest_valid_step(ckpt_dir: str, before: Optional[int] = None) -> int:
    """Newest step that passes :func:`verify_checkpoint` (optionally
    strictly below ``before``); -1 when none does."""
    for step in reversed(list_steps(ckpt_dir)):
        if before is not None and step >= before:
            continue
        try:
            verify_checkpoint(ckpt_dir, step)
            return step
        except (CheckpointCorruptError, OSError):
            continue
    return -1


def restore_latest_valid(ckpt_dir: str, like, shardings=None
                         ) -> Tuple[object, int]:
    """Elastic restore of the newest VALID checkpoint: steps are tried
    newest-first, each integrity-verified (checksums) before restore; a
    torn or corrupt step is reported and skipped. Template mismatches
    (strict ``KeyError``/``ValueError`` from :func:`restore_checkpoint`)
    still propagate — a config error must never be "fixed" by silently
    rolling back to an older checkpoint that happens to match.

    Returns ``(tree, step)``; raises ``FileNotFoundError`` when no valid
    checkpoint exists at all."""
    steps = list_steps(ckpt_dir)
    for step in reversed(steps):
        try:
            verify_checkpoint(ckpt_dir, step)
        except (CheckpointCorruptError, OSError) as e:
            print(f"[ckpt] step {step} failed verification ({e}); "
                  f"falling back to the previous checkpoint", flush=True)
            continue
        return restore_checkpoint(ckpt_dir, step, like,
                                  shardings=shardings), step
    raise FileNotFoundError(
        f"no valid checkpoint in {ckpt_dir!r} "
        f"({len(steps)} step dir(s) present, all failed verification)"
        if steps else f"no checkpoint step_* directories in {ckpt_dir!r}")


def gc_checkpoints(ckpt_dir: str, keep_last_k: int) -> list:
    """Retention GC: delete all but the newest ``keep_last_k`` committed
    steps — EXCEPT the newest step that verifies, which is never deleted
    even when older than the retention window (if every retained step is
    torn/corrupt, the last restorable state must survive). Returns the
    deleted step numbers."""
    if keep_last_k < 1:
        raise ValueError(f"keep_last_k must be >= 1: {keep_last_k}")
    steps = list_steps(ckpt_dir)
    if len(steps) <= keep_last_k:
        return []
    keep = set(steps[-keep_last_k:])
    # newest-first: in the healthy case the newest kept step verifies on
    # the first try and the scan stops there
    if not any(_is_valid(ckpt_dir, s)
               for s in sorted(keep, reverse=True)):
        newest_valid = latest_valid_step(ckpt_dir)
        if newest_valid >= 0:
            keep.add(newest_valid)
    deleted = []
    for step in steps:
        if step in keep:
            continue
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{step:08d}"),
                      ignore_errors=True)
        deleted.append(step)
    return deleted


def _is_valid(ckpt_dir: str, step: int) -> bool:
    try:
        verify_checkpoint(ckpt_dir, step)
        return True
    except (CheckpointCorruptError, OSError):
        return False


def checkpoint_size_report(ckpt_dir: str, step: int) -> dict:
    """Byte accounting from the manifest (no array loads): total logical
    bytes, total saved shard bytes (== logical iff no replica was written
    twice — the no-hidden-all-gather invariant), and per-device owned
    bytes (what each dp rank's process would write in a multi-host run)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    logical = saved = 0
    per_device: dict = {}
    for meta in manifest["leaves"].values():
        itemsize = _np_dtype(meta["dtype"]).itemsize
        logical += int(np.prod(meta["shape"])) * itemsize
        for e in meta["shards"]:
            nbytes = int(np.prod([b - a for a, b in e["index"]])) * itemsize
            saved += nbytes
            per_device[e["device"]] = per_device.get(e["device"], 0) + nbytes
    files = {name: os.path.getsize(os.path.join(d, name))
             for name in os.listdir(d)}
    return {"logical_bytes": logical, "saved_bytes": saved,
            "per_device_bytes": per_device, "file_bytes": files}
