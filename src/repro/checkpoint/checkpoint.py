"""Elastic sharded checkpointing: shard-local saves + shard-overlap lazy
restore (the DeepSpeed ZeRO-partitioned-checkpoint contract), multi-host
correct.

Format ``repro-elastic-ckpt/v2`` — one directory per step, committed by a
single atomic rename performed by process 0:

    step_00000010/
      manifest.json        merged manifest: union of every process's shards
      manifest-p00.json    process 0's per-process manifest (kept for audit)
      manifest-p01.json    process 1's per-process manifest
      shards-p00.npz       process 0's unique addressable shards (raw bytes)
      shards-p01.npz       process 1's unique addressable shards

Save is **shard-local**: each process iterates its arrays'
``addressable_shards`` and writes only shards with ``replica_id == 0`` —
replicated leaves are written exactly once (by whichever process owns
replica 0), ZeRO/pp-sharded leaves contribute exactly their partition, and
nothing is ever gathered across hosts, so per-process bytes stay at shard
size. Host/scalar leaves (step counters, rng) are owned by process 0 only.
The manifest records, per logical leaf: dtype, logical shape, the
PartitionSpec it was saved under, and for every shard its ``[start, stop)``
index ranges plus the owning device id and process — enough to reassemble
the logical array under ANY target layout (and to account bytes per device
and per process; see ``scripts/zero_memory_table.py --ckpt-sizes``).

Commit protocol (the merge barrier):

1. every process stages into its own private ``step_N.tmp-pNN/`` dir —
   shard npz first, then ``manifest-pNN.json`` written atomically LAST, so
   the per-process manifest's presence marks that stage as complete;
2. process 0 waits (bounded by ``MERGE_BARRIER_TIMEOUT``; raises
   :class:`CheckpointBarrierTimeout` naming the stragglers) until all
   ``processes`` per-process manifests exist;
3. process 0 merges them (:func:`merge_manifests`), validating that every
   leaf's shard union covers its logical element count EXACTLY — the
   ``saved_bytes == logical_bytes`` invariant: an under-covered leaf means
   a lost shard, an over-covered one means duplicate ownership (e.g. a
   host leaf written by more than one process);
4. process 0 moves every stage's files into ``step_N.tmp``, writes the
   merged ``manifest.json``, and performs the ONE ``os.rename`` commit —
   no other process ever touches the shared final path, so there is no
   rmtree/rename race.

Restore is **elastic and lazy**: for each leaf the target sharding's
``addressable_devices_indices_map`` gives this host's local partition;
only manifest shards whose index ranges INTERSECT that partition are read
from disk (per-member, checksum-verified), assembled into per-device
blocks, and combined with ``jax.make_array_from_single_device_arrays`` —
per-host restore memory and IO are O(local partition), not O(logical
model). A run saved at dp=8 restores into dp=2×pp=2 or dp=4×zero=3
unchanged. With ``shardings=None`` the full logical arrays are assembled
on host (numpy) instead. :func:`last_restore_stats` reports
logical/read/partition bytes and shard-entry counters for the most recent
restore; :func:`restore_local_shards` exposes the per-process lazy plan
directly (the multi-host simulation/test surface).

Template mismatches are never tolerated: missing/unexpected leaf paths
raise ``KeyError`` naming them, shape/dtype mismatches raise ``ValueError``
with both sides printed, and incomplete shard coverage raises.

Async saves (:class:`AsyncCheckpointer`) keep checkpoint cadence off the
step critical path: the device→host shard snapshot happens synchronously
(the double buffer — after it returns the live arrays may be donated
away), serialization runs on a background thread, the directory rename is
the commit point, and in-flight saves are bounded with backpressure.

Hardened IO (the resilience layer — ROADMAP "Resilience"):

* the manifest records a **crc32 per shard**; restore verifies every
  shard it reads and raises :class:`CheckpointCorruptError` on mismatch
  (or on an unreadable shard file) instead of silently loading garbage;
* save IO retries transient ``OSError``s with jittered-exponential
  backoff (`repro.resilience.backoff`) — the per-process staging is
  idempotent, so a half-written attempt is simply rebuilt. A merge
  barrier timeout is a :class:`CheckpointBarrierTimeout` (RuntimeError,
  deliberately NOT an OSError) so the IO retry never re-runs a full
  barrier wait;
* npz handles are opened through a closing cache (:class:`_NpzCache`) —
  a ``restore_latest_valid`` fallback scan over many torn steps holds no
  leaked fds;
* :func:`restore_latest_valid` falls back to the **newest valid earlier
  step** when the latest is torn or corrupt, and :func:`latest_step`
  skips manifest-less and ``*.tmp*`` directories instead of tripping;
* :func:`gc_checkpoints` retains the newest ``keep_last_k`` steps but
  NEVER deletes the newest step that verifies — and reports only steps
  whose removal actually succeeded (a failed rmtree is warned about and
  excluded, so retention accounting is truthful). GC runs on process 0
  only.

Multi-host simulation: :func:`simulate_processes` patches the process
index/count and the device→process mapping seen by save/restore, so a
single-controller test can produce genuine per-process staged saves, merge
them, and restore per-process partitions — see
``tests/test_multihost_ckpt.py`` and the ``multihost-ckpt`` CI job.
Legacy ``repro-elastic-ckpt/v1`` checkpoints remain restorable (their
single merged manifest is read as-is).
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import re
import shutil
import threading
import time
import zlib
from typing import Optional, Tuple

import jax
import ml_dtypes
import numpy as np

from repro.core import sharding as shd
from repro.resilience import faults as _faults
from repro.resilience.backoff import BackoffPolicy

FORMAT = "repro-elastic-ckpt/v2"
LEGACY_FORMATS = ("repro-elastic-ckpt/v1",)

# save-side IO retry: a handful of quick attempts — a checkpoint that
# cannot land within this budget is a real outage, not a blip
DEFAULT_IO_BACKOFF = BackoffPolicy(max_attempts=4, base_delay=0.05,
                                   multiplier=2.0, max_delay=1.0,
                                   jitter=0.5)

# merge barrier: how long process 0 waits for every per-process manifest
# before declaring the save torn (module attribute so tests can tighten it)
MERGE_BARRIER_TIMEOUT = 120.0
_BARRIER_POLL = 0.05


class CheckpointCorruptError(ValueError):
    """Checkpoint bytes fail verification (checksum mismatch, unreadable
    shard file, missing manifest) — the restore-fallback trigger."""


class CheckpointBarrierTimeout(RuntimeError):
    """Process 0 gave up waiting for another process's per-process
    manifest at the merge barrier. Deliberately NOT an OSError (and not
    ``TimeoutError``, which IS one): the save-side IO retry must not
    re-run a full barrier wait."""


# ---------------------------------------------------------------------------
# multi-host seams: real values in production, patchable for simulation
# ---------------------------------------------------------------------------

_SIM: Optional[tuple] = None    # (process_index, process_count, device_map)


def _process_index() -> int:
    return _SIM[0] if _SIM is not None else jax.process_index()


def _process_count() -> int:
    return _SIM[1] if _SIM is not None else jax.process_count()


def _device_process(dev) -> int:
    """Which process owns ``dev``. Real runs read ``device.process_index``;
    under :func:`simulate_processes` devices are partitioned contiguously
    by id (or by the caller's explicit mapping)."""
    if _SIM is None:
        return int(dev.process_index)
    _, count, device_map = _SIM
    if device_map is not None:
        return int(device_map(dev))
    return (int(dev.id) * count) // jax.device_count()


@contextlib.contextmanager
def simulate_processes(process_index: int, process_count: int,
                       device_process=None):
    """Pretend this controller is process ``process_index`` of
    ``process_count``: save writes only that process's shard partition
    and :func:`restore_local_shards` reads only its restore partition.
    ``device_process(device) -> int`` overrides the default contiguous
    device→process mapping. Test-only — never nest with live async saves
    from a different simulated process."""
    global _SIM
    if not 0 <= process_index < process_count:
        raise ValueError(
            f"process_index {process_index} outside [0, {process_count})")
    prev = _SIM
    _SIM = (int(process_index), int(process_count), device_process)
    try:
        yield
    finally:
        _SIM = prev


# ---------------------------------------------------------------------------
# small shared helpers
# ---------------------------------------------------------------------------

def _np_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):           # DictKey / FlattenedIndexKey
            parts.append(str(k.key))
        elif hasattr(k, "name"):        # GetAttrKey (TrainState fields)
            parts.append(str(k.name))
        elif hasattr(k, "idx"):         # SequenceKey (tuples, OptState)
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _flatten(tree) -> list:
    """[(key, leaf)] in tree order (keys are stable across save/restore
    because both sides flatten the same structure)."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(_path_str(path), leaf) for path, leaf in flat]


def _index_ranges(index, shape) -> list:
    out = []
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else int(sl.start)
        stop = int(dim) if sl.stop is None else int(sl.stop)
        out.append([start, stop])
    return out


def _range_count(ranges) -> int:
    return int(np.prod([b - a for a, b in ranges]))


def _intersect(a, b) -> Optional[tuple]:
    """Intersection of two ``[start, stop)`` range lists, or None when
    empty. NOTE: the scalar-leaf intersection is the empty tuple ``()``
    (falsy but a REAL full overlap) — callers must test ``is None``."""
    out = []
    for (a0, a1), (b0, b1) in zip(a, b):
        lo, hi = max(int(a0), int(b0)), min(int(a1), int(b1))
        if lo >= hi:
            return None
        out.append((lo, hi))
    return tuple(out)


def _entry_process(entry: dict) -> int:
    """Owning process of a manifest shard entry; legacy v1 entries carry
    no ``process`` field, so fall back to the shard filename."""
    if "process" in entry:
        return int(entry["process"])
    m = re.match(r"shards-p(\d+)\.npz$", entry.get("file", ""))
    return int(m.group(1)) if m else 0


class _NpzCache:
    """Open-npz cache that CLOSES every handle deterministically — the
    fd-leak fix: restore/verify scans over many steps must not accumulate
    open ``NpzFile``s."""

    def __init__(self, d: str):
        self._d = d
        self._open: dict = {}

    def get(self, fname: str):
        if fname not in self._open:
            self._open[fname] = np.load(os.path.join(self._d, fname))
        return self._open[fname]

    def close(self):
        files, self._open = list(self._open.values()), {}
        for f in files:
            try:
                f.close()
            except Exception:   # noqa: BLE001 — torn zip close is fine
                pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


# ---------------------------------------------------------------------------
# save: snapshot (device -> host, shard-local) then write (host only)
# ---------------------------------------------------------------------------

def _snapshot(tree) -> dict:
    """Host-side copy of every unique addressable shard this PROCESS owns
    (replica 0 only) — the double buffer an async save serializes from.
    No cross-device or cross-host gather happens here: one ``device_get``
    per owned shard. Host/scalar leaves are owned by process 0 only (every
    process claiming them would write duplicate shards and break the
    ``saved_bytes == logical_bytes`` invariant). The process index/count
    are captured HERE, synchronously — the async writer thread must not
    consult the (possibly since-changed) seams."""
    proc, procs = _process_index(), _process_count()
    snap = {"mesh": None, "leaves": {}, "process": proc, "processes": procs}
    for key, leaf in _flatten(tree):
        if hasattr(leaf, "addressable_shards"):
            # np.array(copy=True), NOT np.asarray: on CPU backends the
            # latter returns a zero-copy VIEW of the live device buffer,
            # which would alias memory the caller is about to donate —
            # the copy is what makes this a double buffer
            shards = [(_index_ranges(sh.index, leaf.shape),
                       np.array(sh.data, copy=True), int(sh.device.id))
                      for sh in leaf.addressable_shards
                      if sh.replica_id == 0
                      and _device_process(sh.device) == proc]
            desc = shd.describe_sharding(leaf)
            shape, dtype = tuple(leaf.shape), str(np.dtype(leaf.dtype))
        else:                           # host numpy / python scalar leaf
            arr = np.asarray(leaf)
            shards = ([([[0, d] for d in arr.shape], arr, 0)]
                      if proc == 0 else [])
            desc, shape, dtype = None, arr.shape, str(arr.dtype)
        if desc and desc.get("mesh") and snap["mesh"] is None:
            snap["mesh"] = desc["mesh"]
        snap["leaves"][key] = {
            "dtype": dtype, "shape": list(shape),
            "spec": desc["spec"] if desc else None, "shards": shards}
    return snap


def _write_snapshot(ckpt_dir: str, step: int, snap: dict) -> str:
    """Serialize a snapshot into this process's PRIVATE staging dir
    ``step_N.tmp-pNN/`` (shard npz first, per-process manifest atomically
    last — the stage-complete marker), then, on process 0 only, run the
    merge-barrier commit. Idempotent — a retried attempt rebuilds the
    staging dir from scratch. No process but 0 ever touches the shared
    final path, so there is no rmtree/rename race."""
    _faults.check("ckpt_write", step)   # chaos harness (no-op in prod)
    proc, procs = snap["process"], snap["processes"]
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    stage = f"{final}.tmp-p{proc:02d}"
    if os.path.isdir(stage):
        shutil.rmtree(stage)
    os.makedirs(stage)
    shard_file = f"shards-p{proc:02d}.npz"
    arrays, leaves = {}, {}
    slot = 0
    for key, meta in snap["leaves"].items():
        entries = []
        for ranges, data, dev in meta["shards"]:
            k = f"a{slot}"
            slot += 1
            # raw bytes: npz cannot serialize ml_dtypes (bfloat16 etc.)
            raw = data.tobytes()
            arrays[k] = np.frombuffer(raw, np.uint8)
            entries.append({"file": shard_file, "key": k,
                            "shape": list(data.shape), "index": ranges,
                            "device": dev, "process": proc,
                            "crc32": zlib.crc32(raw)})
        leaves[key] = {"dtype": meta["dtype"], "shape": meta["shape"],
                       "spec": meta["spec"], "shards": entries}
    np.savez(os.path.join(stage, shard_file), **arrays)
    manifest = {"format": FORMAT, "step": step, "process": proc,
                "processes": procs, "mesh": snap["mesh"], "leaves": leaves}
    mpath = os.path.join(stage, f"manifest-p{proc:02d}.json")
    mtmp = mpath + ".part"
    with open(mtmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(mtmp, mpath)             # barrier poll never sees torn JSON
    if proc != 0:
        return final                    # process 0 commits for everyone
    path = _commit_step(ckpt_dir, step, procs)
    _faults.corrupt_committed(path, step)   # chaos harness (no-op in prod)
    return path


def _await_manifests(ckpt_dir: str, step: int, processes: int) -> dict:
    """Merge barrier: block until every process's ``manifest-pNN.json``
    exists (bounded by ``MERGE_BARRIER_TIMEOUT``, read at call time so
    tests can tighten it). Returns {process: manifest path}."""
    paths = {
        p: os.path.join(ckpt_dir, f"step_{step:08d}.tmp-p{p:02d}",
                        f"manifest-p{p:02d}.json")
        for p in range(processes)}
    deadline = time.monotonic() + MERGE_BARRIER_TIMEOUT
    while True:
        missing = sorted(p for p, mp in paths.items()
                         if not os.path.isfile(mp))
        if not missing:
            return paths
        if time.monotonic() >= deadline:
            raise CheckpointBarrierTimeout(
                f"step {step}: timed out after {MERGE_BARRIER_TIMEOUT}s "
                f"waiting for per-process manifests from process(es) "
                f"{missing} of {processes} — save is torn, not committed")
        time.sleep(_BARRIER_POLL)


def merge_manifests(manifests: list) -> dict:
    """Merge per-process manifests into the committed ``manifest.json``.

    Validates: unique process ids covering ``0..processes-1``, identical
    format/step, identical leaf key sets (``KeyError``), per-leaf
    dtype/shape/spec agreement across processes, and — the
    ``saved_bytes == logical_bytes`` invariant — that every leaf's shard
    union covers its logical element count EXACTLY (``ValueError`` listing
    every offender: under-coverage means a lost shard, over-coverage means
    duplicate ownership, e.g. a host leaf written by more than one
    process)."""
    if not manifests:
        raise ValueError("no per-process manifests to merge")
    by_proc: dict = {}
    for m in manifests:
        p = int(m["process"])
        if p in by_proc:
            raise ValueError(
                f"duplicate per-process manifest for process {p}")
        by_proc[p] = m
    procs = {int(m["processes"]) for m in manifests}
    steps = {int(m["step"]) for m in manifests}
    fmts = {m.get("format") for m in manifests}
    if len(procs) != 1 or len(steps) != 1 or len(fmts) != 1:
        raise ValueError(
            f"per-process manifests disagree on processes={sorted(procs)} "
            f"step={sorted(steps)} format={sorted(map(str, fmts))}")
    processes, step, fmt = procs.pop(), steps.pop(), fmts.pop()
    expected = set(range(processes))
    if set(by_proc) != expected:
        raise ValueError(
            f"step {step}: per-process manifests cover processes "
            f"{sorted(by_proc)} but the save declared {processes} "
            f"process(es) {sorted(expected)}")
    key_sets = {p: set(m["leaves"]) for p, m in by_proc.items()}
    base_keys = key_sets[0]
    for p, keys in sorted(key_sets.items()):
        if keys != base_keys:
            raise KeyError(
                f"step {step}: process {p} manifest leaf keys disagree "
                f"with process 0 — only in p{p}: "
                f"{sorted(keys - base_keys)}; only in p0: "
                f"{sorted(base_keys - keys)}")
    mesh = next((m["mesh"] for _, m in sorted(by_proc.items())
                 if m.get("mesh")), None)
    leaves: dict = {}
    errors = []
    for key in sorted(base_keys):
        metas = [(p, by_proc[p]["leaves"][key])
                 for p in sorted(by_proc)]
        _, base = metas[0]
        for p, meta in metas[1:]:
            if (meta["dtype"], meta["shape"], meta["spec"]) != (
                    base["dtype"], base["shape"], base["spec"]):
                errors.append(
                    f"  {key}: process {p} disagrees with process 0 on "
                    f"dtype/shape/spec ({meta['dtype']}/{meta['shape']}/"
                    f"{meta['spec']} vs {base['dtype']}/{base['shape']}/"
                    f"{base['spec']})")
        shards = [e for _, meta in metas for e in meta["shards"]]
        logical = int(np.prod(base["shape"]))
        covered = sum(_range_count(e["index"]) for e in shards)
        if covered != logical:
            kind = ("incomplete — a process lost shards"
                    if covered < logical else
                    "duplicate/overlapping — e.g. a host leaf written by "
                    "more than one process")
            errors.append(
                f"  {key}: merged shards cover {covered} of {logical} "
                f"elements ({kind}); saved_bytes == logical_bytes "
                f"invariant violated")
        leaves[key] = {"dtype": base["dtype"], "shape": base["shape"],
                       "spec": base["spec"], "shards": shards}
    if errors:
        raise ValueError(
            f"step {step}: per-process manifest merge failed:\n"
            + "\n".join(errors))
    return {"format": fmt, "step": step, "processes": processes,
            "mesh": mesh, "leaves": leaves}


def _commit_step(ckpt_dir: str, step: int, processes: int) -> str:
    """Process-0-only commit: await every per-process manifest, merge and
    validate, collect all stages into one ``step_N.tmp``, write the merged
    manifest, and atomically rename into place — the single commit point
    that replaces the old every-process rmtree+rename race."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest_paths = _await_manifests(ckpt_dir, step, processes)
    manifests = []
    for p in sorted(manifest_paths):
        with open(manifest_paths[p]) as f:
            manifests.append(json.load(f))
    merged = merge_manifests(manifests)
    tmp = final + ".tmp"
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    for p in sorted(manifest_paths):
        stage = f"{final}.tmp-p{p:02d}"
        for name in (f"shards-p{p:02d}.npz", f"manifest-p{p:02d}.json"):
            src = os.path.join(stage, name)
            if os.path.exists(src):
                os.replace(src, os.path.join(tmp, name))
        shutil.rmtree(stage, ignore_errors=True)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(merged, f, indent=1)
    if os.path.isdir(final):
        shutil.rmtree(final)            # re-save of the same step
    os.rename(tmp, final)
    return final


def _write_with_retry(ckpt_dir: str, step: int, snap: dict,
                      retry: Optional[BackoffPolicy]) -> str:
    """Write, retrying transient IO failures (OSError) with backoff;
    persistent failures — including merge-validation ``ValueError``s and
    :class:`CheckpointBarrierTimeout` — propagate immediately."""
    if retry is None:
        return _write_snapshot(ckpt_dir, step, snap)
    return retry.retry(
        lambda: _write_snapshot(ckpt_dir, step, snap),
        retryable=(OSError,),
        on_retry=lambda a, d, e: print(
            f"[ckpt] save step {step} attempt {a + 1} failed ({e}); "
            f"retrying in {d:.2f}s", flush=True))


def save_checkpoint(ckpt_dir: str, step: int, tree, *,
                    retry: Optional[BackoffPolicy] = DEFAULT_IO_BACKOFF,
                    keep_last_k: int = 0) -> str:
    """Synchronous shard-local save. ``tree`` is any pytree of arrays
    (typically a full ``TrainState``). Transient IO errors are retried
    per ``retry``; ``keep_last_k`` > 0 runs retention GC after the
    commit (process 0 only — every process deleting shared step dirs
    would be the same race the commit protocol just removed)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    snap = _snapshot(tree)
    path = _write_with_retry(ckpt_dir, step, snap, retry)
    if keep_last_k and snap["process"] == 0:
        gc_checkpoints(ckpt_dir, keep_last_k)
    return path


class AsyncCheckpointer:
    """Double-buffered async saver with a bounded in-flight count.

    ``save`` snapshots the shards to host memory synchronously (so the
    caller may immediately donate/overwrite the live arrays) and hands
    serialization to a background thread; when ``max_in_flight`` writes are
    already pending it blocks on the oldest — backpressure instead of
    unbounded host-memory growth. ``wait()`` drains and re-raises the first
    background failure; failures also FAIL FAST on the next ``save``
    (both before and after the backpressure wait — a run must not keep
    training for another ``ckpt_every`` steps on top of a save path that
    is already broken).

    Background writes retry transient IO errors with ``retry`` (the
    hardened-IO policy) and run retention GC when ``keep_last_k`` > 0 —
    on process 0 only, matching the commit protocol. The process identity
    is captured at snapshot time, so a simulated-process save keeps its
    identity even though the write happens later on the writer thread.

    ``close()`` drains WITHOUT raising — the stored failure is logged,
    never swallowed silently — for teardown paths where an exception is
    already in flight; ``__exit__`` closes on an exceptional exit and
    waits (re-raising) on a clean one. ``__del__`` is belt-and-braces
    ``close()``.
    """

    def __init__(self, max_in_flight: int = 2,
                 retry: Optional[BackoffPolicy] = DEFAULT_IO_BACKOFF,
                 keep_last_k: int = 0):
        if max_in_flight < 1:
            raise ValueError(f"max_in_flight must be >= 1: {max_in_flight}")
        self._max = max_in_flight
        self._retry = retry
        self._keep_last_k = keep_last_k
        self._pending: list = []
        self._errors: list = []
        self._lock = threading.Lock()

    def _raise_if_failed(self):
        with self._lock:
            if self._errors:
                err = self._errors[0]
                raise RuntimeError(
                    f"async checkpoint save failed: {err!r}") from err

    def save(self, ckpt_dir: str, step: int, tree) -> str:
        self._raise_if_failed()
        # prune finished writes (long runs would otherwise hold one dead
        # Thread per save), then block on the oldest until under the cap
        while True:
            self._pending = [t for t in self._pending if t.is_alive()]
            if len(self._pending) < self._max:
                break
            self._pending[0].join()
        self._raise_if_failed()
        os.makedirs(ckpt_dir, exist_ok=True)
        snap = _snapshot(tree)          # device -> host, before returning

        def run():
            try:
                _write_with_retry(ckpt_dir, step, snap, self._retry)
                if self._keep_last_k and snap["process"] == 0:
                    gc_checkpoints(ckpt_dir, self._keep_last_k)
            except BaseException as e:  # noqa: BLE001 — surfaced in wait()
                with self._lock:
                    self._errors.append(e)

        t = threading.Thread(target=run, name=f"ckpt-save-{step}",
                             daemon=True)
        self._pending.append(t)
        t.start()
        return os.path.join(ckpt_dir, f"step_{step:08d}")

    def wait(self):
        for t in self._pending:
            t.join()
        self._pending.clear()
        self._raise_if_failed()

    def close(self):
        """Drain in-flight saves without raising; a stored background
        failure is LOGGED (never silently discarded) — the teardown
        counterpart of ``wait()`` for already-failing exits."""
        for t in self._pending:
            t.join()
        self._pending.clear()
        with self._lock:
            errors, self._errors = self._errors, []
        for err in errors:
            print(f"[ckpt] WARNING: async checkpoint save failed "
                  f"(surfaced at close): {err!r}", flush=True)

    def __enter__(self):
        return self

    def __exit__(self, exc_type, *exc):
        # on an exceptional exit, don't mask the in-flight exception with
        # a save failure — close() logs it instead
        if exc_type is not None:
            self.close()
        else:
            self.wait()
        return False

    def __del__(self):
        try:
            if self._pending or self._errors:
                self.close()
        except Exception:   # noqa: BLE001 — interpreter-shutdown tolerant
            pass


# ---------------------------------------------------------------------------
# restore: strict template match, lazy shard-overlap read, target layout
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RestoreStats:
    """Byte/entry accounting for one restore — the O(local partition)
    contract made observable. ``read_bytes`` counts each npz member at
    most once (members are decoded per leaf and reused across the devices
    they overlap); ``partition_bytes`` is the host memory assembled for
    this process's unique blocks."""
    logical_bytes: int = 0
    read_bytes: int = 0
    partition_bytes: int = 0
    entries_total: int = 0
    entries_read: int = 0


_LAST_RESTORE_STATS: Optional[RestoreStats] = None


def last_restore_stats() -> Optional[RestoreStats]:
    """Stats of the most recent :func:`restore_checkpoint` on this
    process (None before any restore)."""
    return _LAST_RESTORE_STATS


class _LeafReader:
    """Per-leaf member reader: decodes each npz member at most once
    (checksum-verified), counts read entries/bytes, and is dropped after
    the leaf — decoded-member memory never outlives one leaf."""

    def __init__(self, d: str, cache: _NpzCache, dtype, stats: RestoreStats,
                 context: str):
        self._d = d
        self._cache = cache
        self._dtype = dtype
        self._stats = stats
        self._context = context
        self._members: dict = {}

    def member(self, entry: dict) -> np.ndarray:
        mk = (entry["file"], entry["key"])
        if mk not in self._members:
            raw = _read_shard_bytes(self._d, entry, self._cache,
                                    context=self._context)
            self._stats.entries_read += 1
            self._stats.read_bytes += len(raw)
            self._members[mk] = np.frombuffer(
                raw, self._dtype).reshape(entry["shape"])
        return self._members[mk]


def _assemble_block(key: str, meta: dict, ranges, reader: _LeafReader
                    ) -> np.ndarray:
    """Assemble ONE contiguous block (``[start, stop)`` per dim) of a
    leaf from the manifest shards that intersect it — the lazy-restore
    core: non-overlapping shards are never read."""
    dtype = _np_dtype(meta["dtype"])
    block = np.empty(tuple(b - a for a, b in ranges), dtype)
    covered = 0
    for e in meta["shards"]:
        inter = _intersect(e["index"], ranges)
        if inter is None:               # () is a REAL scalar overlap
            continue
        sub = reader.member(e)
        src = tuple(slice(lo - a0, hi - a0)
                    for (lo, hi), (a0, _) in zip(inter, e["index"]))
        dst = tuple(slice(lo - r0, hi - r0)
                    for (lo, hi), (r0, _) in zip(inter, ranges))
        block[dst] = sub[src]
        covered += _range_count(inter)
    want = _range_count(ranges)
    if covered != want:
        raise ValueError(
            f"leaf {key}: shards cover {covered} of {want} elements of "
            f"block {ranges} (incomplete or overlapping shard map)")
    return block


def _load_manifest(d: str) -> dict:
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    fmt = manifest.get("format")
    if fmt != FORMAT and fmt not in LEGACY_FORMATS:
        raise ValueError(
            f"checkpoint {d} has format {fmt!r}; this "
            f"restorer reads {FORMAT!r} (and legacy "
            f"{list(LEGACY_FORMATS)}) — refusing to reinterpret shard "
            f"bytes across format versions")
    return manifest


def _validate_template(d: str, leaves_meta: dict, like_items: list) -> None:
    """The strict template contract: ``KeyError`` on leaf-path mismatch,
    ``ValueError`` (all offenders, both sides printed) on shape/dtype
    mismatch or incomplete logical shard coverage."""
    like_keys = [k for k, _ in like_items]
    missing = sorted(set(like_keys) - set(leaves_meta))
    unexpected = sorted(set(leaves_meta) - set(like_keys))
    if missing or unexpected:
        raise KeyError(
            f"checkpoint {d} does not match the restore template — "
            f"missing from checkpoint: {missing or '[]'}; "
            f"unexpected in checkpoint: {unexpected or '[]'}")
    errors = []
    for key, leaf in like_items:
        meta = leaves_meta[key]
        want_shape = tuple(getattr(leaf, "shape", np.shape(leaf)))
        want_dtype = np.dtype(getattr(leaf, "dtype",
                                      np.asarray(leaf).dtype))
        got_shape, got_dtype = tuple(meta["shape"]), _np_dtype(meta["dtype"])
        if got_shape != want_shape or got_dtype != want_dtype:
            errors.append(
                f"  {key}: checkpoint shape={got_shape} "
                f"dtype={got_dtype.name} vs template shape={want_shape} "
                f"dtype={want_dtype.name}")
        covered = sum(_range_count(e["index"]) for e in meta["shards"])
        if covered != int(np.prod(got_shape)):
            errors.append(
                f"  {key}: shards cover {covered} of "
                f"{int(np.prod(got_shape))} elements (incomplete or "
                f"overlapping shard map)")
    if errors:
        raise ValueError(
            f"checkpoint {d} incompatible with restore template:\n"
            + "\n".join(errors))


def _flatten_shardings(shardings, n_leaves: int) -> list:
    if shardings is None:
        return [None] * n_leaves
    flat = jax.tree_util.tree_flatten(shardings)[0]
    if len(flat) != n_leaves:
        raise ValueError(
            f"shardings tree has {len(flat)} leaves but the restore "
            f"template has {n_leaves} — the trees must align leaf-for-leaf")
    return flat


def restore_checkpoint(ckpt_dir: str, step: int, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs; values ignored), resharding to ``shardings`` when
    given (the TARGET engine's NamedShardings — this is the elastic path).

    With shardings, the restore is LAZY: each leaf's target sharding
    yields this process's local partition via
    ``addressable_devices_indices_map``; only manifest shards whose index
    ranges intersect it are read, per-device blocks are deduplicated by
    range, and the leaf is built with
    ``jax.make_array_from_single_device_arrays`` — per-host IO and memory
    are O(local partition). With ``shardings=None`` full logical numpy
    arrays are assembled instead. :func:`last_restore_stats` reports the
    accounting either way.

    Raises ``KeyError`` when the checkpoint and template trees disagree on
    leaf paths, and ``ValueError`` (all offenders listed, both sides
    printed) on any shape/dtype mismatch or incomplete shard coverage.
    """
    global _LAST_RESTORE_STATS
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = _load_manifest(d)
    leaves_meta = manifest["leaves"]
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    like_items = [(_path_str(path), leaf) for path, leaf in flat_like]
    _validate_template(d, leaves_meta, like_items)
    flat_sh = _flatten_shardings(shardings, len(like_items))

    stats = RestoreStats()
    out_leaves = []
    with _NpzCache(d) as cache:
        for (key, _), sharding in zip(like_items, flat_sh):
            meta = leaves_meta[key]
            dtype = _np_dtype(meta["dtype"])
            shape = tuple(meta["shape"])
            stats.logical_bytes += int(np.prod(shape)) * dtype.itemsize
            stats.entries_total += len(meta["shards"])
            reader = _LeafReader(d, cache, dtype, stats, key)
            if sharding is None:
                block = _assemble_block(
                    key, meta, [[0, dim] for dim in shape], reader)
                stats.partition_bytes += block.nbytes
                out_leaves.append(block)
                continue
            blocks: dict = {}
            arrays = []
            for dev, idx in sharding.addressable_devices_indices_map(
                    shape).items():
                ranges = _index_ranges(idx, shape)
                rkey = tuple(map(tuple, ranges))
                if rkey not in blocks:  # replicated targets assemble once
                    blocks[rkey] = _assemble_block(key, meta, ranges,
                                                   reader)
                    stats.partition_bytes += blocks[rkey].nbytes
                arrays.append(jax.device_put(blocks[rkey], dev))
            out_leaves.append(jax.make_array_from_single_device_arrays(
                shape, sharding, arrays))
    _LAST_RESTORE_STATS = stats
    return jax.tree_util.tree_unflatten(treedef, out_leaves)


def restore_local_shards(ckpt_dir: str, step: int, like, shardings
                         ) -> Tuple[dict, RestoreStats]:
    """THIS process's lazy restore plan, materialized: for each template
    leaf, the per-device blocks of the target sharding's partition that
    belong to local devices (``_device_process(dev) == process_index``),
    assembled from only the intersecting manifest shards.

    Returns ``({leaf_key: [(device_id, ranges, block), ...]}, stats)``
    where ``ranges`` is the block's ``((start, stop), ...)`` and ``block``
    the host numpy data. This is the multi-host simulation/test surface —
    production restores go through :func:`restore_checkpoint`, whose
    ``addressable_devices_indices_map`` is already per-host on a real
    multi-host runtime."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = _load_manifest(d)
    leaves_meta = manifest["leaves"]
    flat_like = jax.tree_util.tree_flatten_with_path(like)[0]
    like_items = [(_path_str(path), leaf) for path, leaf in flat_like]
    _validate_template(d, leaves_meta, like_items)
    flat_sh = _flatten_shardings(shardings, len(like_items))
    if any(s is None for s in flat_sh):
        raise ValueError("restore_local_shards requires target shardings")

    proc = _process_index()
    stats = RestoreStats()
    out: dict = {}
    with _NpzCache(d) as cache:
        for (key, _), sharding in zip(like_items, flat_sh):
            meta = leaves_meta[key]
            dtype = _np_dtype(meta["dtype"])
            shape = tuple(meta["shape"])
            stats.logical_bytes += int(np.prod(shape)) * dtype.itemsize
            stats.entries_total += len(meta["shards"])
            reader = _LeafReader(d, cache, dtype, stats, key)
            blocks: dict = {}
            plan = []
            for dev, idx in sharding.addressable_devices_indices_map(
                    shape).items():
                if _device_process(dev) != proc:
                    continue
                ranges = _index_ranges(idx, shape)
                rkey = tuple(map(tuple, ranges))
                if rkey not in blocks:
                    blocks[rkey] = _assemble_block(key, meta, ranges,
                                                   reader)
                    stats.partition_bytes += blocks[rkey].nbytes
                plan.append((int(dev.id), rkey, blocks[rkey]))
            out[key] = plan
    return out, stats


def _read_shard_bytes(d: str, entry: dict, npz_cache: _NpzCache, *,
                      context: str) -> bytes:
    """One shard's raw bytes, checksum-verified against the manifest.
    Unreadable files (torn zip, IO error) and crc mismatches both raise
    :class:`CheckpointCorruptError` — the fallback-restore trigger."""
    try:
        raw = npz_cache.get(entry["file"])[entry["key"]].tobytes()
    except Exception as e:  # noqa: BLE001 — any read failure = corrupt
        raise CheckpointCorruptError(
            f"checkpoint {d}: shard file {entry['file']!r} "
            f"(leaf {context}, key {entry['key']}) unreadable: "
            f"{e!r}") from e
    if "crc32" in entry and zlib.crc32(raw) != entry["crc32"]:
        raise CheckpointCorruptError(
            f"checkpoint {d}: shard {entry['key']} of leaf {context} "
            f"fails crc32 verification (manifest {entry['crc32']}, "
            f"bytes {zlib.crc32(raw)}) — torn or corrupt write")
    return raw


def verify_checkpoint(ckpt_dir: str, step: int) -> None:
    """Full integrity check of one step: manifest present with the right
    format, every shard file readable, every per-shard crc32 matching.
    Raises :class:`CheckpointCorruptError` (or ``FileNotFoundError`` for
    a missing manifest); returns None when the checkpoint is sound.
    Pre-checksum (manifests without ``crc32``) checkpoints pass on
    readability alone."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest_path = os.path.join(d, "manifest.json")
    try:
        with open(manifest_path) as f:
            manifest = json.load(f)
    except FileNotFoundError:
        raise
    except Exception as e:  # noqa: BLE001 — torn manifest = corrupt
        raise CheckpointCorruptError(
            f"checkpoint {d}: manifest unreadable: {e!r}") from e
    fmt = manifest.get("format")
    if fmt != FORMAT and fmt not in LEGACY_FORMATS:
        raise CheckpointCorruptError(
            f"checkpoint {d}: format {fmt!r} != {FORMAT!r}")
    with _NpzCache(d) as npz_cache:
        for key, meta in manifest["leaves"].items():
            for e in meta["shards"]:
                _read_shard_bytes(d, e, npz_cache, context=key)


def list_steps(ckpt_dir: str) -> list:
    """All committed step numbers, ascending. A step counts only when
    its ``manifest.json`` exists — ``*.tmp`` / ``*.tmp-pNN`` staging dirs
    (never renamed in) and manifest-less torn directories are skipped,
    not tripped on."""
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(
        int(m.group(1)) for name in os.listdir(ckpt_dir)
        if (m := re.match(r"step_(\d+)$", name))
        and os.path.isfile(os.path.join(ckpt_dir, name, "manifest.json")))


def latest_step(ckpt_dir: str) -> int:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else -1


def latest_valid_step(ckpt_dir: str, before: Optional[int] = None) -> int:
    """Newest step that passes :func:`verify_checkpoint` (optionally
    strictly below ``before``); -1 when none does."""
    for step in reversed(list_steps(ckpt_dir)):
        if before is not None and step >= before:
            continue
        try:
            verify_checkpoint(ckpt_dir, step)
            return step
        except (CheckpointCorruptError, OSError):
            continue
    return -1


def restore_latest_valid(ckpt_dir: str, like, shardings=None
                         ) -> Tuple[object, int]:
    """Elastic restore of the newest VALID checkpoint: steps are tried
    newest-first, each integrity-verified (checksums) before restore; a
    torn or corrupt step is reported and skipped. Template mismatches
    (strict ``KeyError``/``ValueError`` from :func:`restore_checkpoint`)
    still propagate — a config error must never be "fixed" by silently
    rolling back to an older checkpoint that happens to match.

    Returns ``(tree, step)``; raises ``FileNotFoundError`` when no valid
    checkpoint exists at all."""
    steps = list_steps(ckpt_dir)
    for step in reversed(steps):
        try:
            verify_checkpoint(ckpt_dir, step)
        except (CheckpointCorruptError, OSError) as e:
            print(f"[ckpt] step {step} failed verification ({e}); "
                  f"falling back to the previous checkpoint", flush=True)
            continue
        return restore_checkpoint(ckpt_dir, step, like,
                                  shardings=shardings), step
    raise FileNotFoundError(
        f"no valid checkpoint in {ckpt_dir!r} "
        f"({len(steps)} step dir(s) present, all failed verification)"
        if steps else f"no checkpoint step_* directories in {ckpt_dir!r}")


def gc_checkpoints(ckpt_dir: str, keep_last_k: int) -> list:
    """Retention GC: delete all but the newest ``keep_last_k`` committed
    steps — EXCEPT the newest step that verifies, which is never deleted
    even when older than the retention window (if every retained step is
    torn/corrupt, the last restorable state must survive). Returns the
    step numbers whose removal actually SUCCEEDED: a failed rmtree is
    warned about (step + error) and excluded, so retention accounting
    never claims bytes that are still on disk."""
    if keep_last_k < 1:
        raise ValueError(f"keep_last_k must be >= 1: {keep_last_k}")
    steps = list_steps(ckpt_dir)
    if len(steps) <= keep_last_k:
        return []
    keep = set(steps[-keep_last_k:])
    # newest-first: in the healthy case the newest kept step verifies on
    # the first try and the scan stops there
    if not any(_is_valid(ckpt_dir, s)
               for s in sorted(keep, reverse=True)):
        newest_valid = latest_valid_step(ckpt_dir)
        if newest_valid >= 0:
            keep.add(newest_valid)
    deleted = []
    for step in steps:
        if step in keep:
            continue
        path = os.path.join(ckpt_dir, f"step_{step:08d}")
        try:
            shutil.rmtree(path)
        except OSError as e:
            print(f"[ckpt] WARNING: retention gc failed to delete step "
                  f"{step} ({path}): {e!r}; keeping it in the listing",
                  flush=True)
            continue
        if os.path.isdir(path):         # belt-and-braces: verify removal
            print(f"[ckpt] WARNING: retention gc left step {step} "
                  f"({path}) on disk; keeping it in the listing",
                  flush=True)
            continue
        deleted.append(step)
    return deleted


def _is_valid(ckpt_dir: str, step: int) -> bool:
    try:
        verify_checkpoint(ckpt_dir, step)
        return True
    except (CheckpointCorruptError, OSError):
        return False


def checkpoint_size_report(ckpt_dir: str, step: int) -> dict:
    """Byte accounting from the manifest (no array loads): total logical
    bytes, total saved shard bytes (== logical iff no replica was written
    twice — the no-hidden-all-gather invariant, enforced at merge time),
    per-device owned bytes, and per-process owned bytes (what each host
    writes in a multi-host run)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = _load_manifest(d)
    logical = saved = 0
    per_device: dict = {}
    per_process: dict = {}
    for meta in manifest["leaves"].values():
        itemsize = _np_dtype(meta["dtype"]).itemsize
        logical += int(np.prod(meta["shape"])) * itemsize
        for e in meta["shards"]:
            nbytes = _range_count(e["index"]) * itemsize
            saved += nbytes
            per_device[e["device"]] = per_device.get(e["device"], 0) + nbytes
            p = _entry_process(e)
            per_process[p] = per_process.get(p, 0) + nbytes
    files = {name: os.path.getsize(os.path.join(d, name))
             for name in os.listdir(d)}
    return {"logical_bytes": logical, "saved_bytes": saved,
            "per_device_bytes": per_device,
            "per_process_bytes": per_process, "file_bytes": files}


def per_process_restore_bytes(ckpt_dir: str, step: int) -> dict:
    """Per-process RESTORE bytes for a same-layout restore, from the
    merged manifest alone (no array loads): a shard covering its whole
    leaf is replicated — every process reads it — while a partial shard
    is read by its owning process. The lazy-restore counterpart of
    ``checkpoint_size_report``'s save-side accounting (the
    ``--ckpt-sizes`` table column)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = _load_manifest(d)
    processes = int(manifest.get("processes", 1))
    out = {p: 0 for p in range(processes)}
    for meta in manifest["leaves"].values():
        itemsize = _np_dtype(meta["dtype"]).itemsize
        logical = int(np.prod(meta["shape"]))
        for e in meta["shards"]:
            count = _range_count(e["index"])
            nbytes = count * itemsize
            if count == logical:        # replicated: every process reads it
                for p in out:
                    out[p] += nbytes
            else:
                p = _entry_process(e)
                out[p] = out.get(p, 0) + nbytes
    return out
