"""Checkpointing: flat-file numpy + JSON manifest, pytree-faithful.

Gathers sharded arrays to host (addressable shards) and restores with the
target sharding applied via device_put — a single-host stand-in for a real
distributed checkpoint layer, with the same save/restore API.
"""
from __future__ import annotations

import json
import os
import re

import jax
import ml_dtypes
import numpy as np


def _np_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = leaf
    return out


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    flat = _flatten(tree)
    manifest = {}
    arrays = {}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        slot = f"a{len(arrays)}"
        # store raw bytes: npz cannot serialize ml_dtypes (bfloat16 etc.)
        arrays[slot] = np.frombuffer(arr.tobytes(), np.uint8)
        manifest[key] = {"slot": slot, "dtype": str(arr.dtype),
                         "shape": list(arr.shape)}
    np.savez(os.path.join(d, "arrays.npz"), **arrays)
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f, indent=1)
    return d


def restore_checkpoint(ckpt_dir: str, step: int, like, shardings=None):
    """`like`: pytree with the target structure (values ignored)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)["leaves"]
    data = np.load(os.path.join(d, "arrays.npz"))

    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, _ in flat_like:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        meta = manifest[key]
        raw = data[meta["slot"]]
        arr = np.frombuffer(raw.tobytes(), _np_dtype(meta["dtype"])) \
            .reshape(meta["shape"])
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree


def latest_step(ckpt_dir: str) -> int:
    if not os.path.isdir(ckpt_dir):
        return -1
    steps = [int(m.group(1)) for name in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)$", name))]
    return max(steps, default=-1)
