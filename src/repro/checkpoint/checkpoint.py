"""Elastic sharded checkpointing: shard-local saves + layout-resharding
restore (the DeepSpeed ZeRO-partitioned-checkpoint contract).

Format — one directory per step, committed by atomic rename:

    step_00000010/
      manifest.json        logical metadata + shard index maps
      shards-p00.npz       process 0's unique addressable shards (raw bytes)

Save is **shard-local**: each process iterates its arrays'
``addressable_shards`` and writes only shards with ``replica_id == 0`` —
replicated leaves are written exactly once, ZeRO/pp-sharded leaves
contribute exactly their partition, and nothing is ever gathered across
hosts, so per-process bytes stay at shard size. The manifest records, per
logical leaf: dtype, logical shape, the PartitionSpec it was saved under,
and for every shard its ``[start, stop)`` index ranges plus the owning
device id — enough to reassemble the logical array under ANY target
layout (and to account bytes-per-device; see
``scripts/zero_memory_table.py --ckpt-sizes``).

Restore is **elastic**: logical arrays are reassembled from the shard
index maps and ``device_put`` against the TARGET shardings (the restoring
engine's param/opt specs, including a pipe-sharded stacked-layer L axis),
so a run saved at dp=8 restores into dp=2×pp=2 or dp=4×zero=3 unchanged.
Template mismatches are never tolerated: missing/unexpected leaf paths
raise ``KeyError`` naming them, shape/dtype mismatches raise ``ValueError``
with both sides printed, and incomplete shard coverage raises.

Async saves (:class:`AsyncCheckpointer`) keep checkpoint cadence off the
step critical path: the device→host shard snapshot happens synchronously
(the double buffer — after it returns the live arrays may be donated
away), serialization runs on a background thread, the directory rename is
the commit point, and in-flight saves are bounded with backpressure.

Multi-host caveat (single-controller repo): every process would write its
own ``shards-p{NN}.npz`` but the manifest is written by process 0 from its
local shard table; a true multi-host deployment needs a manifest merge
barrier. On this repo's single-process meshes the manifest is complete.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import ml_dtypes
import numpy as np

from repro.core import sharding as shd

FORMAT = "repro-elastic-ckpt/v1"


def _np_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):           # DictKey / FlattenedIndexKey
            parts.append(str(k.key))
        elif hasattr(k, "name"):        # GetAttrKey (TrainState fields)
            parts.append(str(k.name))
        elif hasattr(k, "idx"):         # SequenceKey (tuples, OptState)
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _flatten(tree) -> list:
    """[(key, leaf)] in tree order (keys are stable across save/restore
    because both sides flatten the same structure)."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(_path_str(path), leaf) for path, leaf in flat]


def _index_ranges(index, shape) -> list:
    out = []
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else int(sl.start)
        stop = int(dim) if sl.stop is None else int(sl.stop)
        out.append([start, stop])
    return out


# ---------------------------------------------------------------------------
# save: snapshot (device -> host, shard-local) then write (host only)
# ---------------------------------------------------------------------------

def _snapshot(tree) -> dict:
    """Host-side copy of every unique addressable shard (replica 0 only) —
    the double buffer an async save serializes from. No cross-device or
    cross-host gather happens here: one ``device_get`` per owned shard."""
    snap = {"mesh": None, "leaves": {}}
    for key, leaf in _flatten(tree):
        if hasattr(leaf, "addressable_shards"):
            # np.array(copy=True), NOT np.asarray: on CPU backends the
            # latter returns a zero-copy VIEW of the live device buffer,
            # which would alias memory the caller is about to donate —
            # the copy is what makes this a double buffer
            shards = [(_index_ranges(sh.index, leaf.shape),
                       np.array(sh.data, copy=True), int(sh.device.id))
                      for sh in leaf.addressable_shards
                      if sh.replica_id == 0]
            desc = shd.describe_sharding(leaf)
            shape, dtype = tuple(leaf.shape), str(np.dtype(leaf.dtype))
        else:                           # host numpy / python scalar leaf
            arr = np.asarray(leaf)
            shards = [([[0, d] for d in arr.shape], arr, 0)]
            desc, shape, dtype = None, arr.shape, str(arr.dtype)
        if desc and desc.get("mesh") and snap["mesh"] is None:
            snap["mesh"] = desc["mesh"]
        snap["leaves"][key] = {
            "dtype": dtype, "shape": list(shape),
            "spec": desc["spec"] if desc else None, "shards": shards}
    return snap


def _write_snapshot(ckpt_dir: str, step: int, snap: dict) -> str:
    """Serialize a snapshot to ``step_{step}``: shard npz + manifest into a
    tmp directory, then atomic rename-on-complete (readers never observe a
    partial checkpoint; ``latest_step`` ignores ``*.tmp``)."""
    proc = jax.process_index()
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    shard_file = f"shards-p{proc:02d}.npz"
    arrays, leaves = {}, {}
    slot = 0
    for key, meta in snap["leaves"].items():
        entries = []
        for ranges, data, dev in meta["shards"]:
            k = f"a{slot}"
            slot += 1
            # raw bytes: npz cannot serialize ml_dtypes (bfloat16 etc.)
            arrays[k] = np.frombuffer(data.tobytes(), np.uint8)
            entries.append({"file": shard_file, "key": k,
                            "shape": list(data.shape), "index": ranges,
                            "device": dev})
        leaves[key] = {"dtype": meta["dtype"], "shape": meta["shape"],
                       "spec": meta["spec"], "shards": entries}
    np.savez(os.path.join(tmp, shard_file), **arrays)
    if proc == 0:
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"format": FORMAT, "step": step,
                       "mesh": snap["mesh"], "leaves": leaves}, f, indent=1)
    if os.path.isdir(final):
        shutil.rmtree(final)            # re-save of the same step
    os.rename(tmp, final)
    return final


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    """Synchronous shard-local save. ``tree`` is any pytree of arrays
    (typically a full ``TrainState``)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    return _write_snapshot(ckpt_dir, step, _snapshot(tree))


class AsyncCheckpointer:
    """Double-buffered async saver with a bounded in-flight count.

    ``save`` snapshots the shards to host memory synchronously (so the
    caller may immediately donate/overwrite the live arrays) and hands
    serialization to a background thread; when ``max_in_flight`` writes are
    already pending it blocks on the oldest — backpressure instead of
    unbounded host-memory growth. ``wait()`` drains and re-raises the first
    background failure; failures also surface on the next ``save``.
    """

    def __init__(self, max_in_flight: int = 2):
        if max_in_flight < 1:
            raise ValueError(f"max_in_flight must be >= 1: {max_in_flight}")
        self._max = max_in_flight
        self._pending: list = []
        self._errors: list = []
        self._lock = threading.Lock()

    def _raise_if_failed(self):
        with self._lock:
            if self._errors:
                err = self._errors[0]
                raise RuntimeError(
                    f"async checkpoint save failed: {err!r}") from err

    def save(self, ckpt_dir: str, step: int, tree) -> str:
        self._raise_if_failed()
        # prune finished writes (long runs would otherwise hold one dead
        # Thread per save), then block on the oldest until under the cap
        while True:
            self._pending = [t for t in self._pending if t.is_alive()]
            if len(self._pending) < self._max:
                break
            self._pending[0].join()
        self._raise_if_failed()
        os.makedirs(ckpt_dir, exist_ok=True)
        snap = _snapshot(tree)          # device -> host, before returning

        def run():
            try:
                _write_snapshot(ckpt_dir, step, snap)
            except BaseException as e:  # noqa: BLE001 — surfaced in wait()
                with self._lock:
                    self._errors.append(e)

        t = threading.Thread(target=run, name=f"ckpt-save-{step}",
                             daemon=True)
        self._pending.append(t)
        t.start()
        return os.path.join(ckpt_dir, f"step_{step:08d}")

    def wait(self):
        for t in self._pending:
            t.join()
        self._pending.clear()
        self._raise_if_failed()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.wait()
        return False


# ---------------------------------------------------------------------------
# restore: strict template match, reassemble, reshard to target layout
# ---------------------------------------------------------------------------

def restore_checkpoint(ckpt_dir: str, step: int, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs; values ignored), resharding to ``shardings`` when
    given (the TARGET engine's NamedShardings — this is the elastic path).

    Raises ``KeyError`` when the checkpoint and template trees disagree on
    leaf paths, and ``ValueError`` (all offenders listed, both sides
    printed) on any shape/dtype mismatch or incomplete shard coverage.
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest.get("format") != FORMAT:
        raise ValueError(
            f"checkpoint {d} has format {manifest.get('format')!r}; this "
            f"restorer reads {FORMAT!r} — refusing to reinterpret shard "
            f"bytes across format versions")
    leaves_meta = manifest["leaves"]

    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    like_items = [(_path_str(path), leaf) for path, leaf in flat_like]
    like_keys = [k for k, _ in like_items]
    missing = sorted(set(like_keys) - set(leaves_meta))
    unexpected = sorted(set(leaves_meta) - set(like_keys))
    if missing or unexpected:
        raise KeyError(
            f"checkpoint {d} does not match the restore template — "
            f"missing from checkpoint: {missing or '[]'}; "
            f"unexpected in checkpoint: {unexpected or '[]'}")

    errors = []
    for key, leaf in like_items:
        meta = leaves_meta[key]
        want_shape = tuple(getattr(leaf, "shape", np.shape(leaf)))
        want_dtype = np.dtype(getattr(leaf, "dtype",
                                      np.asarray(leaf).dtype))
        got_shape, got_dtype = tuple(meta["shape"]), _np_dtype(meta["dtype"])
        if got_shape != want_shape or got_dtype != want_dtype:
            errors.append(
                f"  {key}: checkpoint shape={got_shape} "
                f"dtype={got_dtype.name} vs template shape={want_shape} "
                f"dtype={want_dtype.name}")
        covered = sum(
            int(np.prod([b - a for a, b in e["index"]]))
            for e in meta["shards"])
        if covered != int(np.prod(got_shape)):
            errors.append(
                f"  {key}: shards cover {covered} of "
                f"{int(np.prod(got_shape))} elements (incomplete or "
                f"overlapping shard map)")
    if errors:
        raise ValueError(
            f"checkpoint {d} incompatible with restore template:\n"
            + "\n".join(errors))

    npz_cache: dict = {}
    out_leaves = []
    for key, _ in like_items:
        meta = leaves_meta[key]
        dtype = _np_dtype(meta["dtype"])
        out = np.zeros(tuple(meta["shape"]), dtype)
        for e in meta["shards"]:
            if e["file"] not in npz_cache:
                npz_cache[e["file"]] = np.load(os.path.join(d, e["file"]))
            raw = npz_cache[e["file"]][e["key"]]
            sub = np.frombuffer(raw.tobytes(), dtype).reshape(e["shape"])
            out[tuple(slice(a, b) for a, b in e["index"])] = sub
        out_leaves.append(out)
    tree = jax.tree_util.tree_unflatten(treedef, out_leaves)
    if shardings is not None:
        # the elastic step: place each logical array against the TARGET
        # layout's sharding — GSPMD-free resharding via device_put
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree


def latest_step(ckpt_dir: str) -> int:
    if not os.path.isdir(ckpt_dir):
        return -1
    steps = [int(m.group(1)) for name in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)$", name))]
    return max(steps, default=-1)


def checkpoint_size_report(ckpt_dir: str, step: int) -> dict:
    """Byte accounting from the manifest (no array loads): total logical
    bytes, total saved shard bytes (== logical iff no replica was written
    twice — the no-hidden-all-gather invariant), and per-device owned
    bytes (what each dp rank's process would write in a multi-host run)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    logical = saved = 0
    per_device: dict = {}
    for meta in manifest["leaves"].values():
        itemsize = _np_dtype(meta["dtype"]).itemsize
        logical += int(np.prod(meta["shape"])) * itemsize
        for e in meta["shards"]:
            nbytes = int(np.prod([b - a for a, b in e["index"]])) * itemsize
            saved += nbytes
            per_device[e["device"]] = per_device.get(e["device"], 0) + nbytes
    files = {name: os.path.getsize(os.path.join(d, name))
             for name in os.listdir(d)}
    return {"logical_bytes": logical, "saved_bytes": saved,
            "per_device_bytes": per_device, "file_bytes": files}
