from repro.checkpoint.checkpoint import (  # noqa: F401
    AsyncCheckpointer,
    checkpoint_size_report,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
