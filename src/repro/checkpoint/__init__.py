from repro.checkpoint.checkpoint import (  # noqa: F401
    AsyncCheckpointer,
    CheckpointCorruptError,
    checkpoint_size_report,
    gc_checkpoints,
    latest_step,
    latest_valid_step,
    list_steps,
    restore_checkpoint,
    restore_latest_valid,
    save_checkpoint,
    verify_checkpoint,
)
