"""Fault tolerance: deterministic fault injection, shared backoff,
and the auto-resume supervisor (see ROADMAP "Resilience")."""
from repro.resilience.backoff import (  # noqa: F401
    BackoffPolicy,
    TransientError,
)
from repro.resilience.faults import (  # noqa: F401
    Fault,
    FaultPlan,
    PermanentFault,
)
from repro.resilience.supervisor import (  # noqa: F401
    RESTARTABLE_EXIT,
    PreemptionFlag,
    child_argv,
    install_preemption_handler,
    supervise,
)
