"""Auto-resume supervisor: keep a training run alive, unattended.

Two halves, matching the two halves of surviving preemption:

* **Inside the training process** — :func:`install_preemption_handler`
  hooks SIGTERM/SIGINT into a :class:`PreemptionFlag` the train loop
  polls between steps. On the first signal the loop performs a
  best-effort emergency checkpoint save and exits with
  :data:`RESTARTABLE_EXIT` (75, ``EX_TEMPFAIL`` — "failure that is
  expected to clear"); a second signal falls through to the default
  handler and kills the process outright (the scheduler always wins).

* **Outside it** — :func:`supervise` relaunches the training command
  until it exits 0, with capped restarts and jittered-exponential
  backoff between attempts (`repro.resilience.backoff`). Children are
  separate processes (fresh JAX runtime, fresh device state — a wedged
  accelerator context never survives into the retry) and resume from
  the newest VALID checkpoint because the relaunched command carries
  ``--resume`` and restore falls back past torn/corrupt steps
  (`repro.checkpoint`). ``launch/train.py --supervise --max-restarts N``
  is the CLI wiring.

The supervisor forwards SIGTERM/SIGINT to the child and stops
restarting once it has been told to shut down itself — preempting the
supervisor preempts the tree.
"""
from __future__ import annotations

import signal
import subprocess
import sys
import time
from typing import Callable, List, Optional

from repro.resilience.backoff import BackoffPolicy

# EX_TEMPFAIL: the run was interrupted (preemption/emergency save), not
# wrong — the supervisor treats every nonzero exit as restartable, but
# this one is also "expected", so it is logged as preemption not crash
RESTARTABLE_EXIT = 75

DEFAULT_RESTART_BACKOFF = BackoffPolicy(
    max_attempts=64,            # the restart CAP is max_restarts, not this
    base_delay=0.5, multiplier=2.0, max_delay=30.0, jitter=0.5)


class PreemptionFlag:
    """Set by the signal handler, polled by the train loop."""

    def __init__(self):
        self.signum: Optional[int] = None

    @property
    def triggered(self) -> bool:
        return self.signum is not None


def install_preemption_handler(signals=(signal.SIGTERM, signal.SIGINT)
                               ) -> PreemptionFlag:
    """Install one-shot handlers: first delivery sets the flag (the loop
    does the emergency save), and the default disposition is restored so
    a second delivery terminates immediately."""
    flag = PreemptionFlag()

    def handler(signum, frame):
        del frame
        flag.signum = signum
        for s in signals:
            signal.signal(s, signal.SIG_DFL)
        print(f"[supervisor] caught signal {signum}: finishing step, "
              f"emergency-saving, then exiting {RESTARTABLE_EXIT}",
              flush=True)

    for s in signals:
        signal.signal(s, handler)
    return flag


def supervise(cmd: List[str], *, max_restarts: int = 3,
              backoff: BackoffPolicy = DEFAULT_RESTART_BACKOFF,
              seed: Optional[int] = None,
              sleep: Callable[[float], None] = time.sleep,
              popen: Callable = subprocess.Popen,
              log: Callable[[str], None] = None) -> int:
    """Run ``cmd`` until it exits 0, relaunching on any nonzero exit (or
    death-by-signal) up to ``max_restarts`` times with backoff delays
    between attempts. Returns the final exit code (0 on success, the
    child's last code when the restart budget is exhausted, or 128+sig
    when the supervisor itself was told to stop).

    ``sleep``/``popen``/``log`` are injectable for deterministic tests.
    """
    if max_restarts < 0:
        raise ValueError(f"max_restarts must be >= 0: {max_restarts}")
    log = log or (lambda m: print(f"[supervisor] {m}", flush=True))
    delays = backoff.delays(seed)
    stop = {"signum": None}
    child = {"proc": None}

    def forward(signum, frame):
        del frame
        stop["signum"] = signum
        if child["proc"] is not None and child["proc"].poll() is None:
            child["proc"].send_signal(signum)

    prev = {s: signal.signal(s, forward)
            for s in (signal.SIGTERM, signal.SIGINT)}
    try:
        for attempt in range(max_restarts + 1):
            log(f"launch attempt {attempt + 1}/{max_restarts + 1}: "
                + " ".join(cmd))
            proc = popen(cmd)
            child["proc"] = proc
            rc = proc.wait()
            child["proc"] = None
            if rc == 0:
                log("run completed cleanly")
                return 0
            why = "preempted (emergency save)" if rc == RESTARTABLE_EXIT \
                else f"died with signal {-rc}" if rc < 0 \
                else f"crashed (exit {rc})"
            if stop["signum"] is not None:
                log(f"child {why}; supervisor was signalled "
                    f"({stop['signum']}) — not restarting")
                return 128 + stop["signum"]
            if attempt >= max_restarts:
                log(f"child {why}; restart budget ({max_restarts}) "
                    f"exhausted — giving up")
                return rc if rc > 0 else 128 - rc
            delay = next(delays, backoff.max_delay)
            log(f"child {why}; restarting from the newest valid "
                f"checkpoint in {delay:.2f}s "
                f"({max_restarts - attempt} restarts left)")
            sleep(delay)
        raise AssertionError("unreachable")  # pragma: no cover
    finally:
        for s, h in prev.items():
            signal.signal(s, h)


def child_argv(argv: List[str]) -> List[str]:
    """The relaunch command for a supervised training run: the
    supervisor's own argv minus the supervision flags, plus ``--resume``
    (idempotent) so every attempt restores the newest valid checkpoint."""
    out, skip = [], False
    for a in argv:
        if skip:
            skip = False
            continue
        if a == "--supervise":
            continue
        if a == "--max-restarts":
            skip = True
            continue
        if a.startswith("--max-restarts="):
            continue
        out.append(a)
    if "--resume" not in out:
        out.append("--resume")
    return [sys.executable, "-m", "repro.launch.train"] + out
