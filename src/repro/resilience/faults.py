"""Deterministic fault injection for chaos-testing the training stack.

A :class:`FaultPlan` is a seed-driven, explicitly-enumerable list of
faults — each one names a *kind*, the step it fires at, and (for IO
faults) whether it is transient or permanent. Instrumented call sites
across the stack consult the installed plan and are exact no-ops when
none is installed (the production fast path):

  kind          fires at (site)                          effect
  ------------  ---------------------------------------  ----------------
  nan_grad      ``poison_batch`` in the train loop       floats -> NaN, so
                (launch/train.py, before the step)       loss/grads blow up
                                                         and the engine's
                                                         anomaly guard trips
  ckpt_write    ``check("ckpt_write")`` in               TransientError
                ``checkpoint._write_snapshot``           (retried by the
                                                         backoff wrapper) or
                                                         PermanentFault
  ckpt_corrupt  ``corrupt_committed`` after the          flips bytes in every
                merge-barrier checkpoint commit          committed shard file
                                                         (checksum verify
                                                         catches it; restore
                                                         falls back)
  data          ``check("data")`` in                     TransientError
                ``DataPipeline.batch_at``                (retried by the
                                                         Prefetcher) or
                                                         PermanentFault
  preempt       ``preempt_due`` in the train loop        SIGTERM to the own
                                                         process (exercises
                                                         the emergency-save
                                                         + supervisor path)

Faults fire **once**: each firing is appended to a JSONL fault log, and
installing a plan with the same log path marks already-fired faults as
consumed — so a supervised run relaunched after a fault does NOT replay
it (``preempt@5`` kills the run exactly once, not on every resume that
re-executes step 5). The log doubles as the chaos-run audit artifact the
CI job uploads.

Steps are deterministic: given explicitly (``FaultPlan.parse``,
``--inject-faults "nan_grad@3,preempt@5"``) or drawn from a seeded RNG
(``FaultPlan.seeded`` / the ``kind@rand`` spec form) — the same seed
always yields the same chaos schedule.
"""
from __future__ import annotations

import json
import os
import random
import signal
import threading
import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.resilience.backoff import TransientError

KINDS = ("nan_grad", "ckpt_write", "ckpt_corrupt", "data", "preempt")
_ALIASES = {"nan": "nan_grad", "sigterm": "preempt", "ckpt": "ckpt_write"}


class PermanentFault(RuntimeError):
    """A planned failure that does NOT resolve on retry (within this
    process); retry wrappers must propagate it immediately."""


@dataclass
class Fault:
    kind: str
    step: int
    mode: str = "transient"         # transient | permanent (IO kinds)
    count: int = 2                  # transient raises before success
    remaining: int = field(init=False)
    fired: bool = field(default=False, init=False)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {KINDS}")
        if self.mode not in ("transient", "permanent"):
            raise ValueError(f"unknown fault mode {self.mode!r}")
        if self.count < 1:
            raise ValueError(f"fault count must be >= 1: {self.count}")
        # permanent: raise on every attempt until the process dies;
        # non-IO kinds are one-shot regardless of mode
        self.remaining = (self.count if self.mode == "transient" else -1) \
            if self.kind in ("ckpt_write", "data") else 1

    @property
    def exhausted(self) -> bool:
        return self.remaining == 0


class FaultPlan:
    """A thread-safe set of planned faults plus the fired-fault log."""

    def __init__(self, faults: Sequence[Fault], log_path: Optional[str]
                 = None):
        self.faults: List[Fault] = list(faults)
        self.log_path = log_path
        self._lock = threading.RLock()
        if log_path and os.path.exists(log_path):
            self._consume_from_log(log_path)

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def parse(cls, spec: str, *, seed: int = 0,
              max_step: Optional[int] = None,
              log_path: Optional[str] = None) -> "FaultPlan":
        """``kind@step[:mode[:count]]`` comma-separated; ``@rand`` draws
        the step from ``random.Random(seed)`` over ``[1, max_step)`` —
        deterministic per seed. E.g.
        ``"nan_grad@3,ckpt_write@4:transient:2,preempt@rand"``."""
        rng = random.Random(seed)
        faults = []
        for tok in filter(None, (t.strip() for t in spec.split(","))):
            head, _, tail = tok.partition(":")
            kind, at, step_s = head.partition("@")
            kind = _ALIASES.get(kind.strip(), kind.strip())
            if not at:
                raise ValueError(f"fault token {tok!r} needs kind@step")
            if step_s == "rand":
                if not max_step or max_step < 2:
                    raise ValueError(
                        f"{tok!r}: @rand needs max_step >= 2 (got "
                        f"{max_step})")
                step = rng.randrange(1, max_step)
            else:
                step = int(step_s)
            mode, count = "transient", 2
            if tail:
                parts = tail.split(":")
                mode = parts[0] or "transient"
                if len(parts) > 1:
                    count = int(parts[1])
            faults.append(Fault(kind, step, mode, count))
        return cls(faults, log_path=log_path)

    @classmethod
    def seeded(cls, seed: int, max_step: int,
               kinds: Sequence[str] = ("nan_grad", "ckpt_corrupt",
                                       "preempt"),
               log_path: Optional[str] = None) -> "FaultPlan":
        """One fault per kind at a seed-deterministic step in
        ``[1, max_step)`` — the acceptance-criteria chaos schedule."""
        rng = random.Random(seed)
        return cls([Fault(_ALIASES.get(k, k), rng.randrange(1, max_step))
                    for k in kinds], log_path=log_path)

    # ------------------------------------------------------------------
    # fired-fault log (once-only across supervisor restarts + artifact)
    # ------------------------------------------------------------------

    def _consume_from_log(self, path: str):
        with open(path) as f:
            for line in f:
                try:
                    rec = json.loads(line)
                except ValueError:
                    continue            # torn final line of a killed run
                for flt in self.faults:
                    if flt.kind == rec.get("kind") and \
                            flt.step == rec.get("step"):
                        flt.remaining = 0
                        flt.fired = True

    def _log(self, flt: Fault, detail: str):
        flt.fired = True
        if not self.log_path:
            return
        rec = {"kind": flt.kind, "step": flt.step, "mode": flt.mode,
               "detail": detail, "pid": os.getpid(),
               "time": round(time.time(), 3)}
        os.makedirs(os.path.dirname(self.log_path) or ".", exist_ok=True)
        with open(self.log_path, "a") as f:
            f.write(json.dumps(rec) + "\n")

    def _match(self, kind: str, step: int) -> Optional[Fault]:
        for flt in self.faults:
            if flt.kind == kind and flt.step == step and not flt.exhausted:
                return flt
        return None

    # ------------------------------------------------------------------
    # injection sites
    # ------------------------------------------------------------------

    def check(self, kind: str, step: int):
        """IO-fault site (``ckpt_write`` / ``data``): raise the planned
        failure, or pass through. Transient faults raise
        :class:`TransientError` ``count`` times then resolve; permanent
        faults raise :class:`PermanentFault` until the process dies."""
        with self._lock:
            flt = self._match(kind, step)
            if flt is None:
                return
            if flt.mode == "permanent":
                if not flt.fired:
                    self._log(flt, "permanent failure injected")
                raise PermanentFault(
                    f"injected permanent {kind} fault at step {flt.step}")
            flt.remaining -= 1
            detail = (f"transient failure "
                      f"({flt.count - flt.remaining}/{flt.count})")
            if flt.remaining == 0:
                self._log(flt, detail + " — will resolve on retry")
            raise TransientError(
                f"injected transient {kind} fault at step {flt.step} "
                f"({detail})")

    def poison_batch(self, batch, step: int, *, resolution: int = 0):
        """``nan_grad`` site: return the batch with every float leaf
        poisoned to NaN (once per planned step — the retry after the
        guard skips the update sees the clean batch again). uint8 image
        batches (the streaming data path) carry no float leaf to
        poison, so the images leaf becomes a float32 NaN batch at
        ``resolution`` (the model input size) — ``device_preprocess``
        passes float batches through untouched, so the NaN still
        reaches the loss and trips the guard."""
        with self._lock:
            flt = self._match("nan_grad", step)
            if flt is None:
                return batch
            flt.remaining = 0
            self._log(flt, "batch poisoned to NaN")

        hit = False

        def poison(x):
            nonlocal hit
            if np.issubdtype(np.asarray(x).dtype, np.floating):
                hit = True
                return x * float("nan")
            return x
        import jax
        out = jax.tree.map(poison, batch)
        img = batch.get("images") if isinstance(batch, dict) else None
        if not hit and img is not None and \
                np.asarray(img).dtype == np.uint8:
            shape = np.asarray(img).shape
            if resolution:
                shape = shape[:1] + (resolution, resolution) + shape[3:]
            out = dict(out)
            out["images"] = np.full(shape, np.nan, np.float32)
        return out

    def corrupt_committed(self, ckpt_path: str, step: int):
        """``ckpt_corrupt`` site: after the merge-barrier commit, flip
        bytes inside EVERY per-process shard file of the committed step —
        a torn/bit-rotted checkpoint that LOOKS complete (merged manifest
        present) but fails checksum verification on restore, regardless of
        which process's shards a lazy restore happens to read."""
        with self._lock:
            flt = self._match("ckpt_corrupt", step)
            if flt is None:
                return
            flt.remaining = 0
            shards = sorted(n for n in os.listdir(ckpt_path)
                            if n.startswith("shards-"))
            if not shards:
                return
            for name in shards:
                target = os.path.join(ckpt_path, name)
                size = os.path.getsize(target)
                if size == 0:
                    continue
                with open(target, "r+b") as f:
                    f.seek(max(0, size // 2))
                    f.write(b"\xde\xad\xbe\xef" * 4)
            self._log(flt, f"corrupted {', '.join(shards)}")

    def preempt_due(self, step: int) -> bool:
        """``preempt`` site: deliver SIGTERM to this process (the real
        signal — the emergency-save handler path is what's under test).
        Returns True when the signal was sent."""
        with self._lock:
            flt = self._match("preempt", step)
            if flt is None:
                return False
            flt.remaining = 0
            self._log(flt, "SIGTERM delivered to own process")
        os.kill(os.getpid(), signal.SIGTERM)
        return True

    # ------------------------------------------------------------------
    # installation (module-level active plan — threading a plan through
    # every signature in the stack would couple all layers to this one)
    # ------------------------------------------------------------------

    def install(self) -> "FaultPlan":
        global _ACTIVE
        _ACTIVE = self
        return self

    def uninstall(self):
        global _ACTIVE
        if _ACTIVE is self:
            _ACTIVE = None

    def __enter__(self):
        return self.install()

    def __exit__(self, *exc):
        self.uninstall()
        return False

    def __repr__(self):
        return ("FaultPlan(" + ", ".join(
            f"{f.kind}@{f.step}:{f.mode}" + ("!" if f.fired else "")
            for f in self.faults) + ")")


_ACTIVE: Optional[FaultPlan] = None


def active() -> Optional[FaultPlan]:
    return _ACTIVE


# module-level shims: exact no-ops when no plan is installed, so the
# instrumented hot paths cost one None check in production
def check(kind: str, step: int):
    if _ACTIVE is not None:
        _ACTIVE.check(kind, step)


def poison_batch(batch, step: int, *, resolution: int = 0):
    if _ACTIVE is None:
        return batch
    return _ACTIVE.poison_batch(batch, step, resolution=resolution)


def corrupt_committed(ckpt_path: str, step: int):
    if _ACTIVE is not None:
        _ACTIVE.corrupt_committed(ckpt_path, step)


def preempt_due(step: int) -> bool:
    return _ACTIVE is not None and _ACTIVE.preempt_due(step)
