"""Jittered-exponential backoff — the ONE retry policy shared by
checkpoint IO (`repro.checkpoint`), the data `Prefetcher`
(`repro.data.pipeline`), and the auto-resume supervisor
(`repro.resilience.supervisor`).

The policy is a frozen value object so call sites can log it, tests can
enumerate its delay schedule without sleeping, and hypothesis can
property-check the invariants every consumer relies on
(tests/test_backoff_props.py):

  * the UNJITTERED schedule is monotone non-decreasing and capped at
    ``max_delay`` (``base_delay * multiplier**k`` clipped);
  * every jittered delay lies within ``raw * (1 ± jitter)`` of its
    unjittered value (and never below 0);
  * exactly ``max_attempts`` attempts are made, with ``max_attempts - 1``
    sleeps between them;
  * the schedule is a pure function of ``seed`` — two policies with the
    same seed produce the identical delay sequence (the determinism the
    fault-injection harness needs for reproducible chaos runs).
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Callable, Iterator, Optional, Tuple


class TransientError(OSError):
    """An error the caller believes will resolve on retry (injected by
    the fault harness; also the marker real IO layers may raise).
    Subclasses OSError so the default retry predicates treat any IO
    error — injected or real — the same way."""


@dataclass(frozen=True)
class BackoffPolicy:
    """``max_attempts`` total tries; delay before retry k (0-based) is
    ``min(base_delay * multiplier**k, max_delay)`` scaled by a uniform
    jitter in ``[1 - jitter, 1 + jitter]``."""
    max_attempts: int = 4
    base_delay: float = 0.05
    multiplier: float = 2.0
    max_delay: float = 2.0
    jitter: float = 0.5

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1: {self.max_attempts}")
        if self.base_delay < 0 or self.max_delay < self.base_delay:
            raise ValueError(
                f"need 0 <= base_delay <= max_delay: "
                f"base={self.base_delay} max={self.max_delay}")
        if self.multiplier < 1:
            raise ValueError(f"multiplier must be >= 1: {self.multiplier}")
        if not 0 <= self.jitter < 1:
            raise ValueError(f"jitter must be in [0, 1): {self.jitter}")

    def raw_delay(self, attempt: int) -> float:
        """Unjittered delay after 0-based ``attempt`` — monotone
        non-decreasing, capped at ``max_delay``."""
        return min(self.base_delay * self.multiplier ** attempt,
                   self.max_delay)

    def delays(self, seed: Optional[int] = None) -> Iterator[float]:
        """The ``max_attempts - 1`` inter-attempt delays. Deterministic
        under a fixed ``seed`` (unseeded -> fresh entropy per call)."""
        rng = random.Random(seed)
        for k in range(self.max_attempts - 1):
            raw = self.raw_delay(k)
            yield raw * (1 + self.jitter * (2 * rng.random() - 1))

    def retry(self, fn: Callable, *, retryable: Tuple[type, ...]
              = (OSError,), seed: Optional[int] = None,
              sleep: Callable[[float], None] = time.sleep,
              on_retry: Optional[Callable] = None):
        """Call ``fn()`` up to ``max_attempts`` times, sleeping a jittered
        delay between attempts. Only ``retryable`` exceptions are retried
        — anything else (a PERSISTENT failure) propagates immediately,
        and the last retryable failure propagates once attempts are
        exhausted. ``on_retry(attempt, delay, exc)`` observes each retry
        (logging hook)."""
        delays = self.delays(seed)
        for attempt in range(self.max_attempts):
            try:
                return fn()
            except retryable as e:  # noqa: PERF203 — retry loop
                if attempt + 1 >= self.max_attempts:
                    raise
                delay = next(delays)
                if on_retry is not None:
                    on_retry(attempt, delay, e)
                sleep(delay)
        raise AssertionError("unreachable")  # pragma: no cover
