"""repro — paper reproduction package.

Sharding-invariant RNG is load-bearing for the whole repo: with the legacy
non-partitionable threefry, GSPMD splits the RNG counter differently per
out-sharding, so ZeRO-3's dp-sharded parameter init draws *different
values* than stage 0/1 on the same seed (breaking the "ZeRO changes
sharding, not math" invariant and any multi-process launcher agreement).
Partitionable threefry makes random draws a pure function of (key, shape)
regardless of mesh/sharding, at no cost on this workload.
"""
import jax

jax.config.update("jax_threefry_partitionable", True)
