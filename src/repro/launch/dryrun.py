import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# (the two lines above MUST precede any jax-importing import — jax locks the
# device count on first init; see the multi-pod dry-run contract)

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (  # noqa: E402
    ASSIGNED_ARCHS,
    EngineConfig,
    applicable,
    get_config,
    get_shape,
)
from repro.configs.shapes import SHAPES  # noqa: E402
from repro.core.comm_model import TPU_V5E  # noqa: E402
from repro.core.engine import DistributedEngine  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import input_specs  # noqa: E402

def comm_time_seconds(coll: dict, hw=TPU_V5E) -> float:
    """Per-device collective time model (§Roofline collective term).

    all-reduce moves ~2x bytes (reduce-scatter + all-gather phases of a
    ring); the others move ~1x their result bytes per device. Bandwidth: 4
    usable ICI links per v5e chip in a 2D torus -> data crosses ~2 links
    concurrently; we charge the per-link bandwidth on the bottleneck link.
    """
    bw = hw.ici_bw
    t = 2.0 * coll["all-reduce"] / bw
    for k in ("all-gather", "reduce-scatter", "all-to-all",
              "collective-permute"):
        t += coll[k] / bw
    return t


def roofline(totals, *, chips: int, model_flops: float,
             hw=TPU_V5E) -> dict:
    """Terms from the trip-count-aware HLO analyzer (per-device program),
    in seconds. XLA's own cost_analysis counts while bodies once — see
    hlo_analysis module docstring."""
    flops = totals.flops
    bytes_acc = totals.hbm_bytes
    coll = totals.coll
    t_compute = flops / hw.peak_flops
    t_memory = bytes_acc / hw.hbm_bw
    t_coll = comm_time_seconds(coll, hw)
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    useful = model_flops / chips / flops if flops else 0.0
    return {
        **terms,
        "dominant": dom,
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": bytes_acc,
        "model_flops_per_dev": model_flops / chips,
        "useful_flops_frac": useful,
        "bound_step_s": max(terms.values()),
    }


def engine_for(arch: str, shape_name: str, mesh, *, zero: int = None,
               seq_parallel: str = None, remat: str = None,
               use_pallas: bool = False, moe_impl: str = None,
               bf16_gather: bool = False, embed: str = None,
               chunk: int = 0, micro: int = 0):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    cfg = cfg.replace(attn_impl="blockwise")
    if remat is not None:
        cfg = cfg.replace(remat=remat)
    elif shape.kind == "train":
        cfg = cfg.replace(remat="block")   # default for big-model training
    if use_pallas:
        cfg = cfg.replace(use_pallas=True)
    if moe_impl:
        cfg = cfg.replace(moe_impl=moe_impl)
    if chunk and cfg.ssm is not None:
        import dataclasses as _dc
        cfg = cfg.replace(ssm=_dc.replace(cfg.ssm, chunk_size=chunk))
    # default policy: ZeRO-3 + TP for train; serving replicates over dp
    if zero is None:
        zero = 3 if shape.kind == "train" else 3
    if seq_parallel is None:
        seq_parallel = "ulysses" if shape.kind == "prefill" else "none"
    dp_world = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp_world *= mesh.devices.shape[mesh.axis_names.index(a)]
    gb = shape.global_batch
    # production default: accumulate down to micro_batch_per_dev == 2 (the
    # paper's gradient-accumulation knob; bounds live activations per device)
    mb = micro or 2
    accum = max(1, gb // (dp_world * mb)) if gb % dp_world == 0 else 1
    ecfg = EngineConfig(
        train_batch_size=max(gb, dp_world) if gb % dp_world == 0 else gb,
        gradient_accumulation_steps=accum,
        zero_stage=zero,
        sequence_parallel=seq_parallel,
        cast_params_bf16=bf16_gather,
        embed_sharding=embed or "vocab",
    )
    if shape.kind != "train":
        # serving engines don't step an optimizer; relax the invariant
        ecfg = ecfg.replace(train_batch_size=dp_world,
                            gradient_accumulation_steps=1)
    return DistributedEngine(cfg, ecfg, mesh), cfg, shape


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             zero: int = None, seq_parallel: str = None, remat: str = None,
             use_pallas: bool = False, verbose: bool = True,
             moe_impl: str = None, bf16_gather: bool = False,
             embed: str = None, chunk: int = 0, micro: int = 0,
             tag: str = "") -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, reason = applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
           "chips": chips, "status": "skip", "reason": reason}
    if not ok:
        return rec

    eng, cfg, shape = engine_for(arch, shape_name, mesh, zero=zero,
                                 seq_parallel=seq_parallel, remat=remat,
                                 use_pallas=use_pallas, moe_impl=moe_impl,
                                 bf16_gather=bf16_gather, embed=embed,
                                 chunk=chunk, micro=micro)
    rec["tag"] = tag
    rec["options"] = {"moe_impl": moe_impl, "bf16_gather": bf16_gather,
                      "embed": embed, "chunk": chunk, "micro": micro,
                      "zero": zero, "seq_parallel": seq_parallel}
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()

    if shape.kind == "train":
        specs = input_specs(cfg, shape)
        lowered = eng.lower_train(specs)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        specs = input_specs(cfg, shape)
        lowered = eng.lower_prefill(specs)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:  # decode: ONE new token against a seq_len cache
        lowered = eng.lower_decode(shape.global_batch, shape.seq_len)
        model_flops = 2.0 * n_active * shape.global_batch

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax<=0.4.x: one dict per program
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    totals = hlo_analysis.analyze(hlo)
    rl = roofline(totals, chips=chips, model_flops=model_flops)
    coll = {k: v for k, v in totals.coll.items()}

    rec.update({
        "status": "ok",
        "params": n_params,
        "active_params": n_active,
        "zero": eng.ecfg.zero_stage,
        "seq_parallel": eng.ecfg.sequence_parallel,
        "argument_bytes_per_dev": getattr(mem, "argument_size_in_bytes", -1),
        "output_bytes_per_dev": getattr(mem, "output_size_in_bytes", -1),
        "temp_bytes_per_dev": getattr(mem, "temp_size_in_bytes", -1),
        "peak_bytes_per_dev": (
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)),
        "collectives": coll,
        "xla_cost_analysis": {k: float(v) for k, v in cost.items()
                              if isinstance(v, (int, float))},
        "roofline": rl,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
    })
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} (pods={2 if multi_pod else 1})"
              f" params={n_params/1e9:.1f}B"
              f" mem/dev={rec['peak_bytes_per_dev']/2**30:.2f}GiB"
              f" dominant={rl['dominant']}"
              f" compute={rl['compute_s']*1e3:.2f}ms"
              f" memory={rl['memory_s']*1e3:.2f}ms"
              f" coll={rl['collective_s']*1e3:.2f}ms"
              f" (lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        sys.stdout.flush()
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (assigned arch x shape)")
    ap.add_argument("--zero", type=int, default=None)
    ap.add_argument("--seq-parallel", default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--moe-impl", default=None)
    ap.add_argument("--bf16-gather", action="store_true")
    ap.add_argument("--embed", default=None)
    ap.add_argument("--chunk", type=int, default=0)
    ap.add_argument("--micro", type=int, default=0)
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args()

    pairs = []
    if args.all:
        for a in ASSIGNED_ARCHS:
            for s in SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    n_fail = 0
    for arch, shape in pairs:
        try:
            rec = run_pair(arch, shape, multi_pod=args.multi_pod,
                           zero=args.zero, seq_parallel=args.seq_parallel,
                           remat=args.remat, use_pallas=args.use_pallas,
                           moe_impl=args.moe_impl,
                           bf16_gather=args.bf16_gather, embed=args.embed,
                           chunk=args.chunk, micro=args.micro,
                           tag=args.tag)
        except Exception as e:   # noqa: BLE001 — report, keep sweeping
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "multi_pod": args.multi_pod,
                   "status": "fail", "error": f"{type(e).__name__}: {e}"}
            n_fail += 1
        if rec["status"] == "skip":
            print(f"[dryrun] {arch} x {shape}: SKIP ({rec['reason']})")
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
