"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns the training/prefill batch specs;
decode inputs (token + cache) come from DistributedEngine.abstract_cache.
Modality frontends are stubbed here per the brief: audio gets precomputed
conv-extractor frame features, VLM gets patch embeddings + M-RoPE grid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    b, s = shape.global_batch, shape.seq_len
    act_dtype = jnp.dtype(cfg.dtype)
    if cfg.arch_type == "audio":
        return {
            "features": sds((b, s, cfg.audio_feat_dim), act_dtype),
            "mask": sds((b, s), jnp.bool_),
            "labels": sds((b, s), jnp.int32),
        }
    if cfg.arch_type == "vlm":
        return {
            "tokens": sds((b, s), jnp.int32),
            "image_embeds": sds((b, cfg.vision_tokens, cfg.d_model),
                                act_dtype),
            "positions": sds((b, s, 3), jnp.int32),
        }
    if cfg.arch_type == "vit":
        return {
            "images": sds((b, cfg.image_size, cfg.image_size, 3),
                          jnp.float32),
            "labels": sds((b,), jnp.int32),
        }
    return {"tokens": sds((b, s), jnp.int32)}


def concrete_batch(cfg: ModelConfig, batch: int, seq: int, *, seed: int = 0):
    """Small concrete batch for smoke tests/examples (same structure)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    if cfg.arch_type == "audio":
        return {
            "features": jax.random.normal(
                ks[0], (batch, seq, cfg.audio_feat_dim), jnp.float32),
            "mask": jax.random.bernoulli(ks[1], 0.2, (batch, seq)),
            "labels": jax.random.randint(ks[2], (batch, seq), 0,
                                         cfg.vocab_size),
        }
    if cfg.arch_type == "vlm":
        n_img = min(cfg.vision_tokens, seq // 2)
        return {
            "tokens": jax.random.randint(ks[0], (batch, seq), 0,
                                         cfg.vocab_size),
            "image_embeds": jax.random.normal(
                ks[1], (batch, n_img, cfg.d_model), jnp.float32),
        }
    if cfg.arch_type == "vit":
        return {
            "images": jax.random.normal(
                ks[0], (batch, cfg.image_size, cfg.image_size, 3)),
            "labels": jax.random.randint(ks[1], (batch,), 0,
                                         cfg.num_classes),
        }
    return {"tokens": jax.random.randint(ks[0], (batch, seq), 0,
                                         cfg.vocab_size)}
