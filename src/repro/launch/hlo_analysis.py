"""Post-SPMD HLO analyzer: per-device FLOPs, HBM traffic and collective
bytes with *while-loop trip counts applied*.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis counts each
``while`` body ONCE — a scan-over-layers transformer therefore under-counts
FLOPs by ~num_layers x, and ZeRO-3's per-layer all-gathers vanish from any
naive line grep. This analyzer parses the optimized HLO module, evaluates
each computation bottom-up, and multiplies through ``known_trip_count``
backend configs (present for lax.scan/fori loops).

Accounting conventions (documented for §Roofline):
  flops       — dot/convolution MACs x2 (the MXU term; elementwise VPU work
                is not counted — it is never the v5e bottleneck for these
                models at bf16).
  hbm_bytes   — sum over *top-level* instructions of operand+result bytes
                (fusion bodies internalize their temporaries, so post-fusion
                call-site traffic approximates HBM traffic).
  collectives — result-shape bytes per op kind, trip-multiplied.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "token": 0,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _shape_list(txt: str):
    """All `dtype[dims]` shapes in txt -> [(dtype, [dims...]), ...]."""
    out = []
    for m in _SHAPE_RE.finditer(txt):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


def _bytes_of(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    op: str
    result_shapes: list
    operand_shapes: list   # resolved via the computation symbol table
    line: str
    calls: list = field(default_factory=list)   # computation names
    trip: int = 1                               # for while


@dataclass
class Totals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll: dict = None

    def __post_init__(self):
        if self.coll is None:
            self.coll = {k: 0.0 for k in COLLECTIVE_KINDS}

    def add(self, other, mult=1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k in COLLECTIVE_KINDS:
            self.coll[k] += other.coll[k] * mult

    @property
    def coll_bytes(self):
        return sum(self.coll.values())


# computation headers sit at column 0, end with '{' and contain '->'; params
# may be tuple-typed (nested parens), so match only the leading name.
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_INSTR = re.compile(r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OP = re.compile(r"^((?:\([^)]*\))|(?:[\w\[\]{},\s/*]+?))\s*([\w\-]+)\(")
_CALLS = re.compile(r"(?:calls=|to_apply=|body=)%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)')
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_NAME = re.compile(r"%([\w.\-]+)")


def parse_module(hlo: str) -> dict:
    """-> {comp_name: [Instr, ...]}, plus '__entry__' key."""
    comps = {}
    entry = None
    cur = None
    symtab = {}
    for raw in hlo.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line)
        if hdr and not raw.startswith(" ") and line.endswith("{") \
                and "->" in line:
            cur = hdr.group(2)
            comps[cur] = []
            symtab = {}
            # parameters declared in the header: name: type pairs
            for pm in re.finditer(r"([\w.\-]+)\s*:\s*([^,)]+)", line):
                symtab[pm.group(1)] = _shape_list(pm.group(2))
            if hdr.group(1):
                entry = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        rhs = m.group(3)
        om = _OP.match(rhs)
        if not om:
            continue
        result_part, op = om.group(1), om.group(2)
        # operands: inside the top-level parens following the op name
        tail = rhs[om.end():]
        depth = 1
        args_chars = []
        for ch in tail:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            args_chars.append(ch)
        args = "".join(args_chars)
        attrs = tail[len(args) + 1:]
        # operand shapes: inline literals + symbol-table lookups (this HLO
        # dump style prints operands as bare %names)
        opnd = _shape_list(args)
        for nm in _OPERAND_NAME.findall(args):
            opnd.extend(symtab.get(nm, []))
        inst = Instr(
            name=m.group(2), op=op,
            result_shapes=_shape_list(result_part),
            operand_shapes=opnd,
            line=line.strip(),
        )
        symtab[inst.name] = inst.result_shapes
        inst.calls = _CALLS.findall(attrs)
        bm = _BRANCHES.search(attrs)
        if bm:
            inst.calls += [c.strip().lstrip("%")
                           for c in bm.group(1).split(",")]
        tm = _TRIP.search(attrs)
        if tm:
            inst.trip = int(tm.group(1))
        comps[cur].append(inst)
    comps["__entry__"] = entry
    return comps


def _dot_flops(inst: Instr) -> float:
    res = 1
    for dt, dims in inst.result_shapes:
        for d in dims:
            res *= d
    cm = _CONTRACT.search(inst.line)
    contract = 1
    if cm and inst.operand_shapes:
        lhs_dims = inst.operand_shapes[0][1]
        for ax in cm.group(1).split(","):
            if ax:
                contract *= lhs_dims[int(ax)]
    return 2.0 * res * contract


def _conv_flops(inst: Instr) -> float:
    res = 1
    for dt, dims in inst.result_shapes:
        for d in dims:
            res *= d
    if len(inst.operand_shapes) >= 2:
        kdims = inst.operand_shapes[1][1]
        k = 1
        for d in kdims:
            k *= d
        # output spatial x kernel-per-output ~ res * k / out_channels
        out_ch = inst.result_shapes[0][1][-1] if inst.result_shapes[0][1] \
            else 1
        return 2.0 * res * k / max(out_ch, 1)
    return 0.0


# per-op HBM traffic model. The key subtlety: in-place ops on scan-carried
# tensors (dynamic-update-slice, while-carry copies) move only the UPDATED
# bytes on real hardware — charging full operand+result would overcount a
# layer-scan's KV-cache update by O(layers x cache) (quadratic artifact).
_FREE_OPS = frozenset((
    "bitcast", "reshape", "get-tuple-element", "tuple", "parameter",
    "constant", "after-all", "copy-done", "all-reduce-done",
    "all-gather-done", "collective-permute-done", "optimization-barrier",
    "partition-id", "replica-id", "domain", "custom-call-done",
))
_RESULT_2X = frozenset((
    "copy", "copy-start", "transpose", "slice", "dynamic-slice", "gather",
    "reverse", "pad", "iota", "broadcast", "rng", "rng-bit-generator",
))


def _op_traffic(inst: Instr) -> float:
    op = inst.op
    if op in _FREE_OPS:
        return 0.0
    if op in _RESULT_2X:
        return 2.0 * _bytes_of(inst.result_shapes)
    if op in ("dynamic-update-slice", "scatter", "select-and-scatter"):
        upd = inst.operand_shapes[1:2]     # the update operand
        return 3.0 * _bytes_of(upd)
    base = op.replace("-start", "")
    if base in COLLECTIVE_KINDS:
        return 2.0 * _bytes_of(inst.result_shapes)
    # generic elementwise / reduce / concat / compare / convert ...
    return _bytes_of(inst.operand_shapes) + _bytes_of(inst.result_shapes)


def analyze(hlo: str) -> Totals:
    comps = parse_module(hlo)
    entry = comps.pop("__entry__")
    memo = {}

    def eval_comp(name: str) -> Totals:
        if name in memo:
            return memo[name]
        memo[name] = Totals()        # cycle guard
        t = Totals()
        for inst in comps.get(name, []):
            op = inst.op
            if op == "dot":
                t.flops += _dot_flops(inst)
                t.hbm_bytes += _bytes_of(inst.operand_shapes) + \
                    _bytes_of(inst.result_shapes)
            elif op == "convolution":
                t.flops += _conv_flops(inst)
                t.hbm_bytes += _bytes_of(inst.operand_shapes) + \
                    _bytes_of(inst.result_shapes)
            elif op in ("fusion", "call", "conditional", "while",
                        "custom-call", "async-start"):
                sub = Totals()
                if op == "conditional":
                    branches = [eval_comp(c) for c in inst.calls]
                    if branches:
                        best = max(branches, key=lambda b: b.flops)
                        sub.add(best)
                else:
                    for c in inst.calls:
                        sub.add(eval_comp(c))
                mult = inst.trip if op == "while" else 1
                t.add(sub, mult)
                if op == "fusion":
                    # call-site traffic only (body temps live in regs/VMEM)
                    t.hbm_bytes += _bytes_of(inst.operand_shapes) + \
                        _bytes_of(inst.result_shapes)
                elif op == "custom-call":
                    t.hbm_bytes += _bytes_of(inst.operand_shapes) + \
                        _bytes_of(inst.result_shapes)
            else:
                t.hbm_bytes += _op_traffic(inst)
                base = op.replace("-start", "") if op.endswith("-start") \
                    else op
                if base in COLLECTIVE_KINDS and not op.endswith("-done"):
                    t.coll[base] += _bytes_of(inst.result_shapes)
        memo[name] = t
        return t

    return eval_comp(entry)


def top_contributors(hlo: str, n: int = 20, by: str = "bytes"):
    """Attribute traffic/flops to individual instructions, with effective
    while-trip multipliers — the dry-run 'profiler' used by §Perf to find
    what to optimize next. Returns [(score, mult, comp, line), ...]."""
    comps = parse_module(hlo)
    entry = comps.pop("__entry__")

    # effective multiplier per computation (top-down over the call graph)
    mult = {entry: 1.0}
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        for inst in comps.get(cname, []):
            m = mult[cname] * (inst.trip if inst.op == "while" else 1)
            for c in inst.calls:
                mult[c] = mult.get(c, 0.0) + m
                if c not in seen:
                    seen.add(c)
                    order.append(c)

    rows = []
    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for inst in instrs:
            if inst.op in ("fusion", "while", "call", "conditional"):
                if inst.op != "fusion":
                    continue
            if by == "flops":
                score = _dot_flops(inst) if inst.op == "dot" else 0.0
            elif inst.op == "fusion":
                score = _bytes_of(inst.operand_shapes) + \
                    _bytes_of(inst.result_shapes)
            else:
                score = _op_traffic(inst)
            if score:
                rows.append((score * m, m, cname, inst.line[:160]))
    rows.sort(key=lambda r: -r[0])
    return rows[:n]
