"""Production mesh construction.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax

from repro.configs.base import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (TPU v5e pod); 2 pods over DCN when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MeshConfig(data=16, model=16, pod=2 if multi_pod else 1)


def make_local_mesh(model: int = 1):
    """Test/bench mesh over whatever devices exist (1 on this container
    unless a subprocess sets xla_force_host_platform_device_count)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))
