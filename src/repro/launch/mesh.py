"""Production mesh construction.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax

from repro.configs.base import MeshConfig


def make_production_mesh(*, multi_pod: bool = False, pipe: int = 1):
    """16x16 = 256 chips/pod (TPU v5e pod); 2 pods over DCN when multi_pod.

    ``pipe > 1`` carves the pipeline axis out of the data axis (pipeline
    stages talk over the torus ring; dp gradient reductions shrink by the
    same factor) — axis convention ("pod",) + ("data", "pipe", "model").
    """
    assert 16 % pipe == 0, pipe
    shape = (16 // pipe, pipe, 16) if pipe > 1 else (16, 16)
    axes = ("data", "pipe", "model") if pipe > 1 else ("data", "model")
    if multi_pod:
        shape, axes = (2,) + shape, ("pod",) + axes
    return jax.make_mesh(shape, axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MeshConfig(data=16, model=16, pod=2 if multi_pod else 1)


def make_local_mesh(model: int = 1, pipe: int = 1):
    """Test/bench mesh over whatever devices exist (1 on this container
    unless a subprocess sets xla_force_host_platform_device_count).

    ``pipe > 1`` inserts the pipeline axis between data and model:
    ("data", "pipe", "model") — dp extent is whatever remains. The pipe
    extent is the number of physical pipeline devices S; interleaved
    virtual stages (EngineConfig.pipeline_interleave) subdivide each
    device's layer range without changing the mesh."""
    n = len(jax.devices())
    assert n % (model * pipe) == 0, (n, model, pipe)
    if pipe > 1:
        return jax.make_mesh((n // (model * pipe), pipe, model),
                             ("data", "pipe", "model"))
    return jax.make_mesh((n // model, model), ("data", "model"))
