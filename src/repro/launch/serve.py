"""Serving driver: prefill a prompt batch, then batched greedy decode with a
sharded KV/state cache (the `serve_step` the decode input-shapes lower).

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --smoke \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _maybe_reexec(devices: int):
    if devices and os.environ.get("_REPRO_REEXEC") != "1":
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={devices}")
        os.environ["_REPRO_REEXEC"] = "1"
        os.execv(sys.executable, [sys.executable] + sys.argv)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args()
    _maybe_reexec(args.devices)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import EngineConfig, get_config, get_smoke_config
    from repro.core.engine import DistributedEngine
    from repro.launch.mesh import make_local_mesh
    from repro.models import transformer as model

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    cfg = cfg.replace(dtype="float32")
    assert cfg.supports_decode(), f"{cfg.name} has no decode step"
    mesh = make_local_mesh(model=args.model_axis)
    dp = mesh.devices.shape[0]
    eng = DistributedEngine(cfg, EngineConfig(train_batch_size=dp), mesh)

    max_len = args.prompt_len + args.gen
    params = eng.init_state(seed=0).params
    with mesh:
        cache = model.init_cache(cfg, args.batch, max_len, jnp.float32)
        prompt = jax.random.randint(jax.random.PRNGKey(0),
                                    (args.batch, args.prompt_len), 0,
                                    cfg.vocab_size)
        cache_shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache)
        prefill = eng.jit_prefill(
            {"tokens": jax.ShapeDtypeStruct(prompt.shape, jnp.int32)},
            cache_shapes)
        decode = eng.jit_decode_step(cache_shapes, donate=False)

        t0 = time.time()
        last_logits, cache = prefill(params, {"tokens": prompt}, cache)
        tok = jnp.argmax(last_logits[:, -1], -1)[:, None].astype(jnp.int32)
        t_prefill = time.time() - t0

        out = [tok]
        t0 = time.time()
        for i in range(args.gen - 1):
            tok, cache = decode(params, cache, tok,
                                jnp.int32(args.prompt_len + i))
            out.append(tok)
        t_decode = time.time() - t0

    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prefill({args.prompt_len} tok)={t_prefill*1e3:.1f}ms "
          f"decode={t_decode*1e3:.1f}ms ({tps:.1f} tok/s)")
    print(f"[serve] sample generations (token ids):\n{gen[:2, :16]}")


if __name__ == "__main__":
    main()
