"""End-to-end training driver (the paper's workload: DeepSpeed-style DP
training of a ViT / LM on a mesh).

Single-host usage (this container):
    PYTHONPATH=src python -m repro.launch.train --arch vit-b16 --smoke \
        --steps 50 --batch 32 --accum 2 --devices 8

--devices N re-execs with xla_force_host_platform_device_count=N so the dp
axis is real (the paper's "N GPUs"), which is how the scaling benchmarks
and multi-device integration tests run on CPU.

--pp N enables 1F1B pipeline parallelism (core/pipeline.py): the layer
stack splits into N contiguous stages over a `pipe` mesh axis carved out of
the device grid (devices = dp x pp x model-axis), with gradient-accumulation
microbatches fed through the pipe — so --accum must be >= N (the 1F1B
fill/drain invariant). --pp composes with --zero (stage-local shards) but
not with --seq-parallel.

--seed seeds both parameter init and the EngineConfig so distributed
layouts are loss-trajectory comparable run-to-run.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _maybe_reexec(devices: int):
    if devices and os.environ.get("_REPRO_REEXEC") != "1":
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={devices}")
        os.environ["_REPRO_REEXEC"] = "1"
        os.execv(sys.executable, [sys.executable] + sys.argv)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vit-b16")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--zero", type=int, default=0)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline stages (1F1B over the `pipe` mesh axis; "
                         "requires --accum >= --pp)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dataset", default="cifar10")
    ap.add_argument("--seq-parallel", default="none")
    ap.add_argument("--use-pallas", action="store_true",
                    help="flash-attention Pallas kernels (custom-VJP train "
                         "path; interpret mode off-TPU)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args()
    _maybe_reexec(args.devices)

    import jax
    import numpy as np

    from repro.checkpoint import save_checkpoint
    from repro.configs import EngineConfig, get_config, get_smoke_config
    from repro.core.engine import DistributedEngine
    from repro.data import DATASETS, DataPipeline
    from repro.launch.mesh import make_local_mesh

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    if args.use_pallas:
        cfg = cfg.replace(use_pallas=True)
    if cfg.arch_type == "vit":
        cfg = cfg.replace(num_classes=DATASETS[args.dataset].num_classes)
    mesh = make_local_mesh(model=args.model_axis, pipe=args.pp)
    dp = mesh.devices.shape[0]
    ecfg = EngineConfig(
        train_batch_size=args.batch,
        gradient_accumulation_steps=args.accum,
        zero_stage=args.zero, optimizer=args.optimizer, lr=args.lr,
        total_steps=args.steps, warmup_steps=max(1, args.steps // 10),
        sequence_parallel=args.seq_parallel, pipeline_stages=args.pp,
        seed=args.seed)
    eng = DistributedEngine(cfg, ecfg, mesh)
    print(f"[train] arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"devices={mesh.devices.size} dp={dp} pp={args.pp} "
          f"micro_batch={ecfg.derived_micro_batch(dp)} accum={args.accum} "
          f"zero={args.zero} opt={args.optimizer}")

    if cfg.arch_type == "vit":
        pipe = DataPipeline(kind="image", global_batch=args.batch,
                            dataset=DATASETS[args.dataset],
                            resolution=cfg.image_size)
    else:
        pipe = DataPipeline(kind="token", global_batch=args.batch,
                            vocab=max(cfg.vocab_size, 2), seq_len=args.seq,
                            epoch_size=args.batch * args.steps)

    params, opt_state = eng.init(seed=args.seed)
    step_fn = eng.jit_train_step()
    hist = []
    t0 = time.time()
    it = iter(pipe.batches())
    import jax.numpy as jnp
    with mesh:
        for step in range(args.steps):
            try:
                batch = next(it)
            except StopIteration:
                it = iter(pipe.batches(epoch=step))
                batch = next(it)
            if cfg.arch_type == "audio":
                from repro.launch.specs import concrete_batch
                batch = concrete_batch(cfg, args.batch, args.seq, seed=step)
            if cfg.arch_type == "vlm":
                from repro.launch.specs import concrete_batch
                batch = concrete_batch(cfg, args.batch, args.seq, seed=step)
            batch = jax.tree.map(jnp.asarray, batch)
            params, opt_state, metrics = step_fn(
                params, opt_state, batch, jnp.int32(step))
            if step % args.log_every == 0 or step == args.steps - 1:
                m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                m["step"] = step
                m["wall_s"] = round(time.time() - t0, 2)
                hist.append(m)
                print(f"[train] step {step:5d} loss={m['loss']:.4f} "
                      f"gnorm={m['grad_norm']:.3f} lr={m['lr']:.2e} "
                      f"({m['wall_s']:.1f}s)")
    if args.ckpt_dir:
        path = save_checkpoint(args.ckpt_dir, args.steps,
                               {"params": params})
        print(f"[train] checkpoint -> {path}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(hist, f, indent=1)
    # final sanity: loss decreased
    if len(hist) >= 2 and not (hist[-1]["loss"] < hist[0]["loss"]):
        print("[train] WARNING: loss did not decrease")
    print(f"[train] done in {time.time()-t0:.1f}s; "
          f"final loss {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
