"""End-to-end training driver (the paper's workload: DeepSpeed-style DP
training of a ViT / LM on a mesh).

Single-host usage (this container):
    PYTHONPATH=src python -m repro.launch.train --arch vit-b16 --smoke \
        --steps 50 --batch 32 --accum 2 --devices 8

--devices N re-execs with xla_force_host_platform_device_count=N so the dp
axis is real (the paper's "N GPUs"), which is how the scaling benchmarks
and multi-device integration tests run on CPU.

--pp N enables 1F1B pipeline parallelism (core/pipeline.py): the layer
stack splits into N contiguous stages over a `pipe` mesh axis carved out of
the device grid (devices = dp x pp x model-axis), with gradient-accumulation
microbatches fed through the pipe — so --accum must be >= N (the 1F1B
fill/drain invariant). The staged executor runs each stage chunk under a
manual per-chunk VJP, keeping only O(pp) microbatch residual sets live at
once (memory flat in --accum, unlike GPipe-style AD-through-schedule).
--pp-interleave v places v virtual stage-chunks per device (Megatron
interleaved 1F1B), shrinking the pipeline bubble from (S-1)/(M+S-1) to
(S-1)/(v*M+S-1) at the cost of v-1 extra inter-device hops per microbatch;
it needs --accum divisible by --pp and num_layers divisible by pp*v.
--pp composes with --zero (stage-local shards), --augment (per-microbatch
rng streams thread through the schedule), and cast_params_bf16 (fp32 grad
accumulation per chunk), but not with --seq-parallel.

--seed seeds both parameter init and the EngineConfig so distributed
layouts are loss-trajectory comparable run-to-run.

Checkpointing & resume (elastic, shard-local — repro.checkpoint, format
``repro-elastic-ckpt/v2``): the loop trains a single ``TrainState`` pytree
(params, opt state, step, data cursor, rng). ``--ckpt-dir D
--ckpt-every N`` saves the full state every N steps via the async
double-buffered saver (off the step critical path; ``--ckpt-sync`` forces
blocking saves) and once more at exit. On multi-host meshes every process
stages its own shards + per-process manifest and process 0 merges and
commits once (the merge-barrier protocol). ``--resume`` restores the
latest state from ``--ckpt-dir`` — into THIS run's dp×pp×ZeRO layout,
whatever layout wrote it, reading only the shards that overlap this
host's partition (lazy shard-overlap restore) — and continues the exact
loss trajectory: same schedule position (state.step), same optimizer
moments, and the same data stream from the saved ``(epoch, batch_index)``
cursor. Keep --steps/--batch/--accum/--seed identical across save and
resume; the layout flags (--devices/--zero/--pp/--model-axis) may change
freely. ``--stop-after K`` ends the loop at step K while the LR schedule
stays built for --steps — the "preempted run" half of the resume-parity CI
check:

    train --steps 6 --stop-after 3 --ckpt-dir D          # preempted
    train --steps 6 --resume --ckpt-dir D                # same trajectory

Real-image workload (the paper's actual experiments): for vit archs,
``--dataset cifar10|cifar100`` feeds the CIFAR source (data/datasets.py) —
the real binary batches when ``--data-dir`` holds them, a deterministic
procedural CIFAR-like stream otherwise (CI never downloads). ``--augment``
turns on the on-device RandomCrop+Flip+Mixup/CutMix recipe inside the
jitted step (rng-threaded from the TrainState, so resumed runs replay the
exact augmentation stream); ``--label-smoothing`` smooths the train CE.
``--eval-every N`` runs the sharded eval loop over the held-out split
every N steps and at exit: integer top-1/top-5 correct counts (exactly
layout-invariant) + NLL, mask-padded over the non-divisible final batch,
appended to the metrics history as eval_* rows.

Fault tolerance (repro.resilience): ``--supervise`` wraps the whole run in
an auto-resume supervisor — the training command runs as a child process
(fresh JAX runtime per attempt) that is relaunched with ``--resume`` from
the newest valid checkpoint after a restartable failure (preemption exit,
crash), up to ``--max-restarts`` times with jittered exponential backoff.
SIGTERM/SIGINT trigger a one-shot emergency checkpoint and a restartable
exit (code 75). The in-jit anomaly guard (on by default; ``--no-guard``
disables) skips any optimizer update whose loss or global grad-norm is
non-finite — params/opt/step stay bitwise unchanged, the SAME cursor
batch is retried, and the run aborts after ``--guard-max-skips``
consecutive skips. ``--keep-last K`` turns on retention GC (never deletes
the newest checkpoint that passes checksum verification).
``--inject-faults "nan_grad@3,ckpt_write@4:transient:2,preempt@rand"``
(or ``seeded``) installs a deterministic chaos schedule — see
resilience/faults.py; fired faults land in ``<ckpt-dir>/faults.jsonl`` so
a supervised relaunch doesn't replay them.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _maybe_reexec(devices: int):
    if devices and os.environ.get("_REPRO_REEXEC") != "1":
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={devices}")
        os.environ["_REPRO_REEXEC"] = "1"
        os.execv(sys.executable, [sys.executable] + sys.argv)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vit-b16")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--zero", type=int, default=0)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline stages (1F1B over the `pipe` mesh axis; "
                         "requires --accum >= --pp)")
    ap.add_argument("--pp-interleave", type=int, default=1,
                    help="virtual stage-chunks per pipeline device "
                         "(Megatron interleaved 1F1B; v>1 shrinks the "
                         "bubble to (S-1)/(v*M+S-1) and requires "
                         "--accum %% --pp == 0 and num_layers %% "
                         "(pp*v) == 0)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dataset", default="cifar10",
                    choices=["cifar10", "cifar100", "synthetic"],
                    help="vit data source: real/procedural CIFAR "
                         "(data/datasets.py) or the legacy synthetic "
                         "tensor stream")
    ap.add_argument("--data-dir", default="",
                    help="directory holding the CIFAR binary batches "
                         "(cifar-10-batches-py / cifar-100-python); unset "
                         "or absent -> deterministic procedural CIFAR "
                         "(no downloads, CI-safe)")
    ap.add_argument("--shard-dir", default="",
                    help="stream from a repro-shards/v1 shard directory "
                         "(data/streaming.py; write one with `python -m "
                         "repro.data.streaming --out DIR`) instead of an "
                         "in-RAM split — overrides --dataset/--data-dir")
    ap.add_argument("--train-size", type=int, default=0,
                    help="truncate/bound the train split to N examples "
                         "(0 = full split; bounds disk + shard splits and "
                         "sizes the procedural stream's epoch)")
    ap.add_argument("--prefetch-depth", type=int, default=2,
                    help="batches in flight at EACH prefetch stage "
                         "(synthesis and host->device transfer run in "
                         "separate threads)")
    ap.add_argument("--eval-every", type=int, default=0,
                    help="evaluate on the held-out split every N steps "
                         "and at the end (0 = no eval; needs a real "
                         "dataset, i.e. --dataset != synthetic)")
    ap.add_argument("--eval-batch", type=int, default=0,
                    help="eval batch size (0 -> --batch); the final "
                         "non-divisible batch is mask-padded")
    ap.add_argument("--eval-size", type=int, default=0,
                    help="truncate the eval split to N examples "
                         "(0 = full split; procedural default "
                         f"is small already)")
    ap.add_argument("--augment", action="store_true",
                    help="on-device RandomCrop+Flip+Mixup/CutMix inside "
                         "the jitted step (vit only, rng-threaded from "
                         "the TrainState so resumes replay the stream)")
    ap.add_argument("--label-smoothing", type=float, default=0.0)
    ap.add_argument("--seq-parallel", default="none")
    ap.add_argument("--use-pallas", action="store_true",
                    help="flash-attention Pallas kernels (custom-VJP train "
                         "path; interpret mode off-TPU)")
    ap.add_argument("--layers", type=int, default=0,
                    help="override cfg.num_layers (0 = config default; "
                         "pipeline layouts need num_layers %% (pp * "
                         "pp-interleave) == 0)")
    ap.add_argument("--dtype", default="",
                    help="override compute dtype (e.g. float32 for the "
                         "cross-layout resume-parity checks, where bf16 "
                         "rounding would mask the comparison)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="save the full TrainState every N steps "
                         "(0 = end-of-run only); async unless --ckpt-sync")
    ap.add_argument("--ckpt-sync", action="store_true",
                    help="blocking saves (debug / bench baseline)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest checkpoint in --ckpt-dir into "
                         "this run's layout and continue the trajectory")
    ap.add_argument("--resume-step", type=int, default=-1,
                    help="restore this specific step instead of the latest")
    ap.add_argument("--stop-after", type=int, default=0,
                    help="stop at this absolute step while the LR schedule "
                         "keeps --steps as its horizon (preemption "
                         "simulation for resume tests)")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="synchronous host data path (bench baseline)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default="")
    # --- resilience ---------------------------------------------------
    ap.add_argument("--supervise", action="store_true",
                    help="run under the auto-resume supervisor: child "
                         "process per attempt, relaunched with --resume "
                         "from the newest valid checkpoint after "
                         "restartable failures")
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="supervisor restart budget")
    ap.add_argument("--inject-faults", default="",
                    help="chaos schedule: 'kind@step[:mode[:count]],...' "
                         "(kinds: nan_grad ckpt_write ckpt_corrupt data "
                         "preempt; '@rand' draws a seeded step) or "
                         "'seeded' for the default seed-derived schedule")
    ap.add_argument("--keep-last", type=int, default=0,
                    help="checkpoint retention: keep the newest K "
                         "(0 = keep all); never deletes the newest "
                         "checkpoint that passes verification")
    ap.add_argument("--no-guard", action="store_true",
                    help="disable the in-jit anomaly guard (non-finite "
                         "loss/grad-norm then corrupts the params)")
    ap.add_argument("--guard-max-skips", type=int, default=3,
                    help="abort after this many consecutive guard-skipped "
                         "updates of the same batch")
    args = ap.parse_args()

    if args.supervise:
        # must run BEFORE _maybe_reexec / any jax import: the supervisor
        # process only forks children and never touches the runtime
        from repro.resilience.supervisor import child_argv, supervise
        raise SystemExit(supervise(child_argv(sys.argv[1:]),
                                   max_restarts=args.max_restarts,
                                   seed=args.seed))
    _maybe_reexec(args.devices)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.checkpoint import latest_step, save_checkpoint
    from repro.configs import EngineConfig, get_config, get_smoke_config
    from repro.core import sharding as shd
    from repro.core.engine import DistributedEngine
    from repro.data import AugmentConfig, DATASETS, DataPipeline, make_source
    from repro.launch.mesh import make_local_mesh
    from repro.resilience import FaultPlan, RESTARTABLE_EXIT
    from repro.resilience import faults as _faults
    from repro.resilience.supervisor import install_preemption_handler

    if args.inject_faults:
        fault_log = os.path.join(args.ckpt_dir, "faults.jsonl") \
            if args.ckpt_dir else None
        if args.inject_faults == "seeded":
            plan = FaultPlan.seeded(args.seed, max_step=args.steps,
                                    log_path=fault_log)
        else:
            plan = FaultPlan.parse(args.inject_faults, seed=args.seed,
                                   max_step=args.steps, log_path=fault_log)
        plan.install()
        print(f"[faults] installed {plan!r}"
              + (f" log={fault_log}" if fault_log else ""))

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    if args.use_pallas:
        cfg = cfg.replace(use_pallas=True)
    if args.dtype:
        cfg = cfg.replace(dtype=args.dtype)
    if args.layers:
        cfg = cfg.replace(num_layers=args.layers)
    # the data source is built BEFORE the engine: a uint8-shipping source
    # hands the engine its Preproc (the on-device normalize/upsample) and
    # its spec names the class count
    source = None
    if cfg.arch_type == "vit" and \
            (args.shard_dir or args.dataset != "synthetic"):
        source = make_source(args.dataset, data_dir=args.data_dir or None,
                             seed=args.seed, resolution=cfg.image_size,
                             train_size=args.train_size or None,
                             eval_size=args.eval_size or None,
                             shard_dir=args.shard_dir or None)
    if cfg.arch_type == "vit":
        spec = source.spec if source is not None else DATASETS["cifar10"]
        cfg = cfg.replace(num_classes=spec.num_classes,
                          label_smoothing=args.label_smoothing)
    mesh = make_local_mesh(model=args.model_axis, pipe=args.pp)
    dp = mesh.devices.shape[0]
    ecfg = EngineConfig(
        train_batch_size=args.batch,
        gradient_accumulation_steps=args.accum,
        zero_stage=args.zero, optimizer=args.optimizer, lr=args.lr,
        total_steps=args.steps, warmup_steps=max(1, args.steps // 10),
        sequence_parallel=args.seq_parallel, pipeline_stages=args.pp,
        pipeline_interleave=args.pp_interleave,
        seed=args.seed, ckpt_every=args.ckpt_every,
        ckpt_async=not args.ckpt_sync, ckpt_keep_last=args.keep_last,
        guard_anomalies=not args.no_guard,
        guard_max_skips=args.guard_max_skips)
    aug = AugmentConfig(num_classes=cfg.num_classes) \
        if args.augment and cfg.arch_type == "vit" else None
    eng = DistributedEngine(
        cfg, ecfg, mesh, aug=aug,
        preproc=source.preproc if source is not None else None)
    print(f"[train] arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"devices={mesh.devices.size} dp={dp} pp={args.pp} "
          f"micro_batch={ecfg.derived_micro_batch(dp)} accum={args.accum} "
          f"zero={args.zero} opt={args.optimizer} "
          f"aug={'on' if aug else 'off'}")

    if cfg.arch_type == "vit":
        if source is not None:
            # real CIFAR from --data-dir when present, a shard stream
            # under --shard-dir, else the deterministic procedural
            # generator — all behind the same cursor contract, all uint8
            # on the host (normalize/upsample run inside the jitted step)
            backing = "shards" if args.shard_dir else \
                "procedural" if source.procedural else "disk"
            print(f"[train] dataset={source.name} {backing} "
                  f"train={source.train_size} eval={source.eval_size}")
            pipe = DataPipeline(kind="image", global_batch=args.batch,
                                source=source, seed=args.seed)
        else:
            pipe = DataPipeline(kind="image", global_batch=args.batch,
                                dataset=DATASETS["cifar10"],
                                resolution=cfg.image_size, seed=args.seed)
    else:
        pipe = DataPipeline(kind="token", global_batch=args.batch,
                            vocab=max(cfg.vocab_size, 2), seq_len=args.seq,
                            epoch_size=args.batch * args.steps,
                            seed=args.seed)
    if args.eval_every and source is None:
        raise SystemExit("[train] --eval-every needs a real dataset "
                         "(--dataset cifar10|cifar100 on a vit arch)")

    state = None
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) >= 0:
        try:
            state = eng.restore_state(
                args.ckpt_dir,
                step=args.resume_step if args.resume_step >= 0 else None)
            print(f"[train] resumed step={int(state.step)} "
                  f"cursor=(epoch {int(state.epoch)}, "
                  f"batch {int(state.batch_index)}) from {args.ckpt_dir}")
        except FileNotFoundError as e:
            # every on-disk step failed checksum verification — a fresh
            # start beats refusing to train (latest-valid fallback for
            # merely-newest-corrupt already happened inside restore_state)
            print(f"[train] --resume: no checkpoint survives "
                  f"verification ({e}); starting fresh")
    if state is None:
        if args.resume and \
                (not args.ckpt_dir or latest_step(args.ckpt_dir) < 0):
            print(f"[train] --resume: no checkpoint in "
                  f"{args.ckpt_dir or '<unset>'}; starting fresh")
        state = eng.init_state(seed=args.seed)
    start_step = int(state.step)
    end_step = min(args.steps, args.stop_after) if args.stop_after \
        else args.steps

    step_fn = eng.jit_train_step()
    saver = eng.make_checkpointer() if ecfg.ckpt_async else None
    preempted = install_preemption_handler()
    hist = []
    t0 = time.time()

    eval_batch = args.eval_batch or args.batch
    eval_fn = eng.jit_eval_step() if args.eval_every else None
    last_eval_step = -1

    def run_eval(state, at_step):
        """Sharded eval over the held-out split; metrics land in history
        (exact integer counts + rates — the layout-invariant signal)."""
        nonlocal last_eval_step
        em = eng.evaluate(state, source.eval_batches(eval_batch),
                          eval_step=eval_fn)
        em["step"] = at_step
        em["wall_s"] = round(time.time() - t0, 2)
        hist.append(em)
        last_eval_step = at_step
        print(f"[eval ] step {at_step:5d} "
              f"top1={em['eval_acc']:.4f} top5={em['eval_top5_acc']:.4f} "
              f"loss={em['eval_loss']:.4f} "
              f"({em['eval_top1_count']}/{em['eval_count']})")

    # cursor-addressable data: vit/token archs ride the background
    # prefetcher; audio/vlm use spec-derived synthetic batches addressed
    # directly by the global step (epoch stays 0 — one endless "epoch")
    cursor_data = cfg.arch_type not in ("audio", "vlm")
    prefetcher = None
    if cursor_data and not args.no_prefetch and start_step < end_step:
        bshard = shd.named(mesh, shd.batch_specs(cfg, pipe.batch_shapes(),
                                                 mesh))
        prefetcher = pipe.prefetch(int(state.epoch), int(state.batch_index),
                                   shardings=bshard,
                                   depth=args.prefetch_depth)

    def fetch(step):
        """-> (batch, cursor-after-this-step)"""
        if not cursor_data:
            from repro.launch.specs import concrete_batch
            batch = concrete_batch(cfg, args.batch, args.seq, seed=step)
            return jax.tree.map(jnp.asarray, batch), (0, step + 1)
        if prefetcher is not None:
            _, batch, nxt = next(prefetcher)
            return batch, nxt
        e, i = int(state.epoch), int(state.batch_index)
        batch = pipe.device_put(pipe.batch_at(e, i))
        return batch, pipe.next_cursor(e, i)

    try:
        with mesh:
            for step in range(start_step, end_step):
                batch, nxt = fetch(step)
                # anomaly-guarded step: a non-finite loss/grad-norm makes
                # the jitted step a bitwise no-op (step_ok=0) — retry the
                # SAME cursor batch (state.step didn't advance, so the
                # fold_in rng stream is identical) and escalate after
                # guard_max_skips consecutive skips. Fault poisoning is
                # once-only, so the retry sees the clean batch — the loss
                # trajectory exactly matches an uninterrupted run.
                skips = 0
                while True:
                    fed = _faults.poison_batch(batch, step,
                                               resolution=cfg.image_size)
                    state, metrics = step_fn(state, fed)
                    if not ecfg.guard_anomalies or \
                            bool(np.asarray(metrics["step_ok"])):
                        break
                    skips += 1
                    print(f"[guard] step {step}: non-finite loss/grad-"
                          f"norm — update skipped "
                          f"({skips}/{ecfg.guard_max_skips})", flush=True)
                    if skips >= ecfg.guard_max_skips:
                        raise RuntimeError(
                            f"anomaly guard: {skips} consecutive skipped "
                            f"updates at step {step}; aborting "
                            f"(persistent data/numerics problem)")
                # roll the data cursor on the host — the jitted step passes
                # it through; a checkpoint taken now names the NEXT batch
                state = state.replace(epoch=jnp.int32(nxt[0]),
                                      batch_index=jnp.int32(nxt[1]))
                if step % args.log_every == 0 or step == end_step - 1:
                    m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                    m["step"] = step
                    m["wall_s"] = round(time.time() - t0, 2)
                    hist.append(m)
                    print(f"[train] step {step:5d} loss={m['loss']:.4f} "
                          f"gnorm={m['grad_norm']:.3f} lr={m['lr']:.2e} "
                          f"({m['wall_s']:.1f}s)")
                if args.ckpt_dir and ecfg.ckpt_every and \
                        (step + 1) % ecfg.ckpt_every == 0:
                    if saver is not None:
                        saver.save(args.ckpt_dir, step + 1, state)
                    else:
                        save_checkpoint(args.ckpt_dir, step + 1, state)
                if args.eval_every and (step + 1) % args.eval_every == 0:
                    run_eval(state, step + 1)
                # planned preemption fires here (SIGTERM to self); real
                # SIGTERM/SIGINT land in the same flag via the handler
                _faults.preempt_due(step)
                if preempted.triggered:
                    if saver is not None:
                        saver.wait()    # drain before the emergency save
                    if args.ckpt_dir:
                        path = save_checkpoint(args.ckpt_dir,
                                               int(np.asarray(state.step)),
                                               state)
                        print(f"[train] preempted (signal "
                              f"{preempted.signum}) — emergency "
                              f"checkpoint -> {path}", flush=True)
                    # EX_TEMPFAIL: the supervisor relaunches with --resume
                    raise SystemExit(RESTARTABLE_EXIT)
    finally:
        if prefetcher is not None:
            prefetcher.close()

    if args.eval_every and int(state.step) != last_eval_step:
        run_eval(state, int(state.step))    # final-state eval
    if saver is not None:
        saver.wait()                    # drain in-flight async saves
    if args.ckpt_dir and latest_step(args.ckpt_dir) != int(state.step):
        path = save_checkpoint(args.ckpt_dir, int(state.step), state)
        print(f"[train] checkpoint -> {path}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(hist, f, indent=1)
    # final sanity: loss decreased (train rows only; eval rows carry
    # eval_* keys instead)
    tr = [h for h in hist if "loss" in h]
    if len(tr) >= 2 and not (tr[-1]["loss"] < tr[0]["loss"]):
        print("[train] WARNING: loss did not decrease")
    final = f"final loss {tr[-1]['loss']:.4f}" if tr \
        else f"no steps run (start={start_step}, end={end_step})"
    print(f"[train] done in {time.time()-t0:.1f}s; {final}")


if __name__ == "__main__":
    main()
