"""DistributedEngine — the DeepSpeed-engine equivalent (the paper's core
artifact) in JAX.

Owns: batch-size invariant (train_batch_size = micro_batch_per_gpu ×
gradient_accumulation_steps × dp_world), gradient accumulation, ZeRO-stage
sharding specs, optimizer, LR schedule, and the pjit'd train / prefill /
decode step functions. ``lower_*`` methods return jax.stages.Lowered for the
multi-pod dry-run and roofline extraction.

Training flows through an explicit :class:`TrainState` pytree — params,
optimizer state, step (also the LR-schedule position), the data-pipeline
cursor ``(epoch, batch_index)`` naming the NEXT batch to consume, and the
base PRNG key — instead of loose ``(params, opt_state)`` tuples. The whole
state is what the elastic checkpoint layer (``repro.checkpoint``) saves and
restores: because every leaf carries its sharding, saves are shard-local
(each process writes only addressable shards) and restores reshard into
whatever dp×pp×ZeRO layout the restoring engine runs
(``DistributedEngine.restore_state``).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import EngineConfig, ModelConfig
from repro.core import pipeline as pipe
from repro.core import sharding as shd
from repro.core import ulysses
from repro.core.grad_accum import _constrain_tree, accumulate_gradients
from repro.models import shardctx
from repro.models import transformer as model
from repro.optim import make_optimizer, make_schedule


@jax.tree_util.register_pytree_with_keys_class
class TrainState:
    """The complete training state, as one pytree.

    Fields:
      params       model parameters (sharded per ZeRO/tp/pp specs)
      opt_state    optimizer state (OptState; ZeRO-sharded)
      step         int32 optimizer step — also the LR-schedule position
      epoch        int32 data-pipeline epoch of the NEXT batch to consume
      batch_index  int32 within-epoch index of the NEXT batch to consume
      rng          base PRNG key; per-step streams derive via
                   ``fold_in(rng, step)`` so a restored state reproduces
                   the exact future randomness without mutating the key

    The cursor convention makes checkpoints resumable mid-epoch: the saved
    ``(epoch, batch_index)`` names the first batch the resumed run feeds.
    ``step``/``epoch``/``batch_index`` duplicate nothing — ``opt_state.step``
    counts optimizer updates (equal to ``step``), while the cursor is owned
    by the host data loop (`launch/train.py`) and passes through the jitted
    step unchanged.
    """
    _fields = ("params", "opt_state", "step", "epoch", "batch_index", "rng")
    __slots__ = _fields

    def __init__(self, *, params, opt_state, step, epoch, batch_index, rng):
        self.params = params
        self.opt_state = opt_state
        self.step = step
        self.epoch = epoch
        self.batch_index = batch_index
        self.rng = rng

    def replace(self, **kw) -> "TrainState":
        vals = {f: getattr(self, f) for f in self._fields}
        bad = set(kw) - set(self._fields)
        if bad:
            raise TypeError(f"unknown TrainState fields: {sorted(bad)}")
        vals.update(kw)
        return TrainState(**vals)

    def tree_flatten_with_keys(self):
        children = [(jax.tree_util.GetAttrKey(f), getattr(self, f))
                    for f in self._fields]
        return children, None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(**dict(zip(cls._fields, children)))

    def __repr__(self):
        return ("TrainState(" + ", ".join(
            f"{f}={jax.tree_util.tree_structure(getattr(self, f))}"
            for f in self._fields) + ")")


class DistributedEngine:
    def __init__(self, cfg: ModelConfig, ecfg: EngineConfig, mesh,
                 aug=None, preproc=None):
        """``aug``: optional :class:`repro.data.augment.AugmentConfig` —
        on-device train-time augmentation applied per microbatch inside
        the jitted step, keyed by the TrainState rng convention
        (``fold_in(state.rng, state.step)`` split per microbatch), so a
        resumed run replays the interrupted run's augmentation stream.

        ``preproc``: optional :class:`repro.data.datasets.Preproc` — the
        dataset's normalization stats + native grid. Required when the
        data path ships uint8 batches (every dataset source does): the
        jitted step then finishes the batch on device — nearest-neighbor
        upsample to ``cfg.image_size`` and the fused cast-and-normalize
        (``data/augment.device_preprocess``). Pass
        ``preproc=source.preproc``. Float batches (the synthetic tensor
        workload) need none and pass through untouched."""
        self.cfg = cfg
        self.ecfg = ecfg
        self.mesh = mesh
        self.aug = aug.validate() if aug is not None else None
        self.preproc = preproc
        if preproc is not None:
            if cfg.arch_type != "vit":
                raise ValueError(
                    f"image preprocessing only applies to vit archs, not "
                    f"{cfg.arch_type!r}")
            if cfg.image_size % preproc.native_resolution:
                raise ValueError(
                    f"cfg.image_size {cfg.image_size} not an integer "
                    f"multiple of the dataset's native "
                    f"{preproc.native_resolution}px grid — the on-device "
                    f"upsample is nearest-neighbor by integer factors")
        if self.aug is not None and cfg.arch_type != "vit":
            raise ValueError(
                f"image augmentation only applies to vit archs, not "
                f"{cfg.arch_type!r}")
        self.dp_world = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                self.dp_world *= mesh.devices.shape[
                    mesh.axis_names.index(a)]
        ecfg.validate(self.dp_world)
        if ecfg.pipeline_stages > 1:
            pipe.check_supported(cfg)
            # interleaved 1F1B places v chunks per device, so the stack
            # must split into S*v equal contiguous chunks
            pipe.stage_partition(
                cfg.num_layers,
                ecfg.pipeline_stages * ecfg.pipeline_interleave)
            ext = dict(zip(mesh.axis_names, mesh.devices.shape))
            if ext.get(pipe.PIPE_AXIS, 1) != ecfg.pipeline_stages:
                raise ValueError(
                    f"pipeline_stages={ecfg.pipeline_stages} needs a "
                    f"'{pipe.PIPE_AXIS}' mesh axis of that extent; mesh has "
                    f"{dict(ext)}")
        self.optimizer = make_optimizer(
            ecfg.optimizer, weight_decay=ecfg.weight_decay,
            grad_clip=ecfg.grad_clip)
        self.schedule = make_schedule(ecfg.lr_schedule, ecfg.lr,
                                      ecfg.warmup_steps, ecfg.total_steps)
        self.hints = ulysses.make_hints(
            mesh, cfg, sequence_parallel=ecfg.sequence_parallel,
            expert_parallel=ecfg.expert_parallel)

    # ------------------------------------------------------------------
    # sharding specs
    # ------------------------------------------------------------------

    def _pspecs(self, shapes, for_opt_state=False):
        return shd.param_specs(
            shapes, zero_stage=self.ecfg.zero_stage,
            tensor_parallel=self.ecfg.tensor_parallel, mesh=self.mesh,
            dp_axes=shd.dp_axes_of(self.mesh), for_opt_state=for_opt_state,
            embed_sharding=self.ecfg.embed_sharding,
            pipeline_axis=pipe.PIPE_AXIS
            if self.ecfg.pipeline_stages > 1 else None)

    def param_shardings(self, param_shapes):
        return shd.named(self.mesh, self._pspecs(param_shapes))

    def opt_shardings(self, param_shapes):
        from repro.optim.optimizers import OptState
        pspec = self._pspecs(param_shapes, for_opt_state=True)
        mu = shd.named(self.mesh, pspec)
        nu = () if self.ecfg.optimizer == "sgd" else mu
        return OptState(step=NamedSharding(self.mesh, P()), mu=mu, nu=nu)

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------

    def init_abstract(self):
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        params = jax.eval_shape(lambda k: model.init_params(self.cfg, k), key)
        opt = jax.eval_shape(self.optimizer.init, params)
        return params, opt

    def abstract_state(self) -> TrainState:
        """ShapeDtypeStruct pytree of the full TrainState (the restore
        template: logical shapes + dtypes, values ignored)."""
        params, opt = self.init_abstract()
        scalar = jax.ShapeDtypeStruct((), jnp.int32)
        return TrainState(params=params, opt_state=opt, step=scalar,
                          epoch=scalar, batch_index=scalar,
                          rng=jax.ShapeDtypeStruct((2,), jnp.uint32))

    def state_shardings(self) -> TrainState:
        """NamedSharding pytree for the TrainState under THIS engine's
        layout — the resharding target for elastic restore."""
        pshapes = self.init_abstract()[0]
        rep = NamedSharding(self.mesh, P())
        return TrainState(params=self.param_shardings(pshapes),
                          opt_state=self.opt_shardings(pshapes),
                          step=rep, epoch=rep, batch_index=rep, rng=rep)

    def init_state(self, seed: int = 0) -> TrainState:
        """Sharded init of the full training state on the mesh."""
        sshard = self.state_shardings()

        @functools.partial(jax.jit, out_shardings=sshard)
        def _init(key):
            params = model.init_params(self.cfg, key)
            zero = jnp.int32(0)
            return TrainState(
                params=params, opt_state=self.optimizer.init(params),
                step=zero, epoch=zero, batch_index=zero,
                # distinct stream from the init key so future stochastic
                # regularizers never correlate with the init draw
                rng=jax.random.fold_in(key, 1))

        with self.mesh:
            return _init(jax.random.PRNGKey(seed))

    # ------------------------------------------------------------------
    # checkpointing (elastic, shard-local — repro.checkpoint)
    # ------------------------------------------------------------------

    def save_state(self, ckpt_dir: str, state: TrainState) -> str:
        """Synchronous shard-local save of the full state; the directory
        name is taken from ``state.step``."""
        from repro.checkpoint import save_checkpoint
        return save_checkpoint(ckpt_dir, int(jax.device_get(state.step)),
                               state)

    def restore_state(self, ckpt_dir: str, step: Optional[int] = None
                      ) -> TrainState:
        """Elastic restore: reassemble logical arrays from the shard index
        maps and reshard into THIS engine's layout — the source run may
        have used any dp×pp×ZeRO layout.

        With ``step=None`` the newest VALID checkpoint is restored:
        every candidate is checksum-verified first and a torn/corrupt
        step falls back to the previous one (the auto-resume contract —
        a preempted run must never be wedged by its own torn last
        write). An explicit ``step`` restores exactly that step, with
        verification errors propagating.

        The restore is lazy (shard-overlap): only manifest shards that
        intersect this host's partition of the target shardings are read
        — the per-host byte accounting is printed after the restore."""
        from repro.checkpoint import last_restore_stats, \
            restore_checkpoint, restore_latest_valid
        if step is None:
            state, _ = restore_latest_valid(
                ckpt_dir, self.abstract_state(),
                shardings=self.state_shardings())
        else:
            state = restore_checkpoint(ckpt_dir, step,
                                       self.abstract_state(),
                                       shardings=self.state_shardings())
        stats = last_restore_stats()
        if stats is not None:
            mib = 1024 * 1024
            print(f"[ckpt] lazy restore: read "
                  f"{stats.read_bytes / mib:.1f} MiB "
                  f"({stats.entries_read}/{stats.entries_total} shards) "
                  f"for a {stats.partition_bytes / mib:.1f} MiB local "
                  f"partition of a {stats.logical_bytes / mib:.1f} MiB "
                  f"logical state", flush=True)
        return state

    def make_checkpointer(self):
        """Async double-buffered checkpointer configured from EngineConfig
        (bounded in-flight saves + retention GC; cadence is the caller's
        ``ckpt_every``)."""
        from repro.checkpoint import AsyncCheckpointer
        return AsyncCheckpointer(
            max_in_flight=self.ecfg.ckpt_max_in_flight,
            keep_last_k=self.ecfg.ckpt_keep_last)

    # ------------------------------------------------------------------
    # train step
    # ------------------------------------------------------------------

    def _preprocess_batch(self, batch):
        """Device-side completion of a host uint8 batch (upsample to
        ``cfg.image_size`` + fused cast-and-normalize); identity on float
        batches. Traced inside the jitted train/eval steps — the
        model-resolution fp32 image tensor never exists on the host."""
        if self.preproc is None:
            return batch
        from repro.data.augment import device_preprocess
        return device_preprocess(batch, self.preproc, self.cfg.image_size)

    def _train_step(self, state: TrainState, batch):
        params, opt_state = state.params, state.opt_state
        # ZeRO-3 §Perf optimization (cast_params_bf16): convert the f32
        # master shards to bf16 BEFORE GSPMD's per-layer all-gather —
        # halves all-gather bytes; master copy/optimizer stay f32.
        compute_params = self._compute_params(params)
        # ZeRO>=2: dp-sharded grad accumulator => per-microstep
        # reduce-scatter instead of a replicated all-reduce
        gspecs = self._pspecs(self.init_abstract()[0],
                              for_opt_state=True) \
            if self.ecfg.zero_stage >= 2 else None
        if self.ecfg.pipeline_stages > 1:
            # 1F1B pipeline route (core/pipeline.py). Runs outside the
            # Ulysses hint context: stage-vectorized activations carry a
            # leading stage axis the (B,S,D) hints don't describe; GSPMD
            # infers layouts from the pipe/dp constraints instead. ZeRO
            # still composes: grads get the same dp-sharded constraint.
            # The staged path threads the SAME fold_in(rng, step)
            # per-microbatch streams as the dp path (augmentation /
            # preprocess run per-microbatch inside the schedule), so a
            # pp run replays the dp run's augmentation stream exactly.
            mb_rngs = jax.random.split(
                jax.random.fold_in(state.rng, state.step),
                self.ecfg.gradient_accumulation_steps)
            grads, metrics = self._pipeline_grads(
                compute_params, batch, gspecs, mb_rngs)
        else:
            with shardctx.use(self.hints):
                # per-step, per-microbatch PRNG streams derived from the
                # state's base key: fold_in(rng, step) makes resumes
                # reproduce future randomness exactly (the key itself
                # never mutates). Deterministic archs ignore them (DCE'd).
                mb_rngs = jax.random.split(
                    jax.random.fold_in(state.rng, state.step),
                    self.ecfg.gradient_accumulation_steps)

                def mb_loss(p, mb, rng):
                    if self.aug is not None:
                        # on-device crop/flip/Mixup/CutMix — pure in the
                        # microbatch rng, so the stream is resumable;
                        # uint8 microbatches are upsampled/normalized
                        # inside (composed with the geometric augs)
                        from repro.data.augment import augment_batch
                        mb = augment_batch(rng, mb, self.aug,
                                           preproc=self.preproc,
                                           resolution=self.cfg.image_size)
                    else:
                        # per-MICROBATCH preprocess: only one microbatch's
                        # upsampled fp32 image tensor is live at a time
                        mb = self._preprocess_batch(mb)
                    return model.loss_fn(self.cfg, p, mb)
                grads, metrics = accumulate_gradients(
                    mb_loss, compute_params, batch,
                    self.ecfg.gradient_accumulation_steps, grad_specs=gspecs,
                    rngs=mb_rngs)
        lr = self.schedule(state.step)
        new_params, new_opt, gnorm = self.optimizer.update(
            grads, opt_state, params, lr)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        new_step = state.step + 1
        if self.ecfg.guard_anomalies:
            # anomaly guard (resilience): a non-finite loss or global
            # grad-norm means the candidate update is garbage — select
            # the INPUT params/opt/step instead, so the step is a pure
            # no-op on the TrainState (cursor/rng semantics untouched;
            # the host loop sees step_ok == 0, retries the same cursor
            # batch, and escalates after guard_max_skips skips). The
            # select is exact when ok: guard on/off trajectories are
            # bitwise identical on healthy steps.
            ok = jnp.isfinite(metrics["loss"]) & jnp.isfinite(gnorm)

            def sel(new, ref):
                return jax.tree.map(lambda a, b: jnp.where(ok, a, b),
                                    new, ref)
            new_params = sel(new_params, params)
            new_opt = sel(new_opt, opt_state)
            new_step = jnp.where(ok, new_step, state.step)
            metrics["step_ok"] = ok.astype(jnp.int32)
        new_state = state.replace(params=new_params, opt_state=new_opt,
                                  step=new_step)
        return new_state, metrics

    def _pipeline_grads(self, compute_params, batch, gspecs, mb_rngs):
        """Mean grads + metrics via the staged 1F1B pipeline — numerically
        interchangeable with ``accumulate_gradients`` over the same
        microbatches and rng streams (the pp-vs-dp parity invariant).

        Uses ``pipelined_value_and_grad`` (manual per-chunk VJPs, O(S·v)
        residual memory) rather than AD through the schedule; gradients
        come back already accumulated in fp32. Augmentation/preprocess
        happen per-microbatch via ``microbatch_fn`` inside the schedule,
        so only one microbatch's fp32 image tensor is live at a time."""
        pspecs = self._pspecs(self.init_abstract()[0])

        def microbatch_fn(mb, rng):
            if self.aug is not None:
                from repro.data.augment import augment_batch
                return augment_batch(rng, mb, self.aug,
                                     preproc=self.preproc,
                                     resolution=self.cfg.image_size)
            return self._preprocess_batch(mb)

        (_, metrics), grads = pipe.pipelined_value_and_grad(
            self.cfg, compute_params, batch,
            stages=self.ecfg.pipeline_stages,
            num_micro=self.ecfg.gradient_accumulation_steps,
            interleave=self.ecfg.pipeline_interleave,
            dp_axes=shd.dp_axes_of(self.mesh),
            pipe_axis=pipe.PIPE_AXIS,
            stack_specs=pipe.stage_stack_specs(pspecs["stack"]),
            rngs=mb_rngs,
            microbatch_fn=microbatch_fn)
        return _constrain_tree(grads, gspecs), metrics

    def jit_train_step(self, batch_shapes=None, donate=True):
        """jit'd ``(TrainState, batch) -> (TrainState, metrics)``. The data
        cursor (epoch/batch_index) passes through unchanged — the host loop
        advances it via ``state.replace`` after each step."""
        sshard = self.state_shardings()
        in_shardings = (sshard,
                        shd.named(self.mesh, shd.batch_specs(
                            self.cfg, batch_shapes, self.mesh))
                        if batch_shapes is not None else None)
        return jax.jit(
            self._train_step,
            in_shardings=in_shardings,
            out_shardings=(sshard, None),
            donate_argnums=(0,) if donate else ())

    def lower_train(self, batch_shapes):
        fn = self.jit_train_step(batch_shapes, donate=False)
        with self.mesh:
            return fn.lower(self.abstract_state(), batch_shapes)

    # ------------------------------------------------------------------
    # evaluation (sharded, padding-mask-aware, layout-invariant)
    # ------------------------------------------------------------------

    def _compute_params(self, params):
        """The train step's compute-dtype view of the params (bf16 gather
        under cast_params_bf16) — eval uses the same view so eval numerics
        match what training actually computes with."""
        if not self.ecfg.cast_params_bf16:
            return params
        return jax.tree.map(
            lambda p: p.astype(jnp.bfloat16)
            if p.dtype == jnp.float32 and p.ndim >= 2 else p, params)

    def _eval_step(self, state: TrainState, batch):
        """No-grad ``(state, batch) -> metrics``: forward + integer
        top-1/top-5 correct counts and an fp32 NLL sum.

        The counts ARE the cross-``data``/``pipe`` reduction: the batch is
        dp-sharded, so the in-jit integer sums lower to all-reduces over
        the dp axes (exact — integer addition is associative), and the
        pipe/model axes compute replicas of the same value. ``mask`` in
        the batch zeroes the padded tail of a non-divisible final eval
        batch. Works under every layout the engine owns, including pp>1:
        the plain scan-over-L forward just gathers pipe-sharded layer
        params (eval needs no 1F1B schedule)."""
        params = self._compute_params(state.params)
        batch = self._preprocess_batch(batch)
        with shardctx.use(self.hints):
            logits, _, _ = model.forward(self.cfg, params, batch,
                                         mode="train")
        return model.classification_counts(logits, batch["labels"],
                                           batch.get("mask"))

    def jit_eval_step(self, batch_shapes=None):
        """jit'd eval step; state is NOT donated (the caller keeps
        training with it)."""
        sshard = self.state_shardings()
        in_shardings = (sshard,
                        shd.named(self.mesh, shd.batch_specs(
                            self.cfg, batch_shapes, self.mesh))
                        if batch_shapes is not None else None)
        return jax.jit(self._eval_step, in_shardings=in_shardings,
                       out_shardings=None)

    def evaluate(self, state: TrainState, batches, *, eval_step=None):
        """Sharded eval loop over an iterator of (padded) eval batches —
        e.g. ``CIFARSource.eval_batches(b)``. Accumulates the per-batch
        integer counts host-side and returns both the exact counts (the
        layout-invariance assertion surface) and the derived rates."""
        if eval_step is None:
            eval_step = self.jit_eval_step()
        top1 = top5 = count = 0
        loss_sum = 0.0
        bshard = None
        with self.mesh:
            for batch in batches:
                if bshard is None:
                    shapes = jax.tree.map(
                        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        batch)
                    bshard = shd.named(self.mesh, shd.batch_specs(
                        self.cfg, shapes, self.mesh))
                batch = jax.tree.map(jax.device_put, batch, bshard)
                m = eval_step(state, batch)
                top1 += int(jax.device_get(m["top1"]))
                top5 += int(jax.device_get(m["top5"]))
                count += int(jax.device_get(m["count"]))
                loss_sum += float(jax.device_get(m["loss_sum"]))
        n = max(count, 1)
        return {
            "eval_top1_count": top1, "eval_top5_count": top5,
            "eval_count": count,
            "eval_acc": top1 / n, "eval_top5_acc": top5 / n,
            "eval_loss": loss_sum / n,
        }

    # ------------------------------------------------------------------
    # serving (prefill / decode)
    # ------------------------------------------------------------------

    def _prefill(self, params, batch, cache):
        with shardctx.use(self.hints):
            logits, new_cache, _ = model.forward(
                self.cfg, params, batch, mode="prefill", cache=cache)
        return logits[:, -1:], new_cache

    def _decode_step(self, params, cache, token, index):
        with shardctx.use(self.hints):
            logits, new_cache, _ = model.forward(
                self.cfg, params, {"token": token, "index": index},
                mode="decode", cache=cache)
        next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        return next_tok.astype(jnp.int32), new_cache

    def cache_shardings(self, cache_shapes):
        return shd.named(self.mesh, shd.cache_specs(
            self.cfg, cache_shapes, self.mesh))

    def abstract_cache(self, batch: int, max_len: int,
                       dtype=jnp.bfloat16):
        return jax.eval_shape(
            lambda: model.init_cache(self.cfg, batch, max_len, dtype))

    def jit_decode_step(self, cache_shapes, donate=True):
        pshapes = self.init_abstract()[0]
        pshard = self.param_shardings(pshapes)
        cshard = self.cache_shardings(cache_shapes)
        return jax.jit(
            self._decode_step,
            in_shardings=(pshard, cshard, NamedSharding(self.mesh, P()),
                          NamedSharding(self.mesh, P())),
            out_shardings=(NamedSharding(self.mesh, P()), cshard),
            donate_argnums=(1,) if donate else ())

    def lower_decode(self, batch: int, cache_len: int):
        pshapes = self.init_abstract()[0]
        cache_shapes = self.abstract_cache(batch, cache_len)
        tok = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
        idx = jax.ShapeDtypeStruct((), jnp.int32)
        fn = self.jit_decode_step(cache_shapes, donate=False)
        with self.mesh:
            return fn.lower(pshapes, cache_shapes, tok, idx)

    def jit_prefill(self, batch_shapes, cache_shapes):
        pshapes = self.init_abstract()[0]
        pshard = self.param_shardings(pshapes)
        cshard = self.cache_shardings(cache_shapes)
        bshard = shd.named(self.mesh,
                           shd.batch_specs(self.cfg, batch_shapes, self.mesh))
        return jax.jit(self._prefill,
                       in_shardings=(pshard, bshard, cshard),
                       out_shardings=(None, cshard))

    def lower_prefill(self, batch_shapes, cache_len: Optional[int] = None):
        pshapes = self.init_abstract()[0]
        bsz, slen = _batch_and_seq(self.cfg, batch_shapes)
        cache_shapes = self.abstract_cache(bsz, cache_len or slen)
        fn = self.jit_prefill(batch_shapes, cache_shapes)
        with self.mesh:
            return fn.lower(pshapes, batch_shapes, cache_shapes)


def _batch_and_seq(cfg, batch_shapes: Any):
    if "tokens" in batch_shapes:
        return batch_shapes["tokens"].shape[:2]
    if "features" in batch_shapes:
        return batch_shapes["features"].shape[:2]
    if "images" in batch_shapes:
        return batch_shapes["images"].shape[0], 0
    raise ValueError(list(batch_shapes))
