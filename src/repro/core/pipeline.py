"""Pipeline parallelism (DeepSpeed PipelineEngine equivalent) on a `pipe`
mesh axis — memory-bounded 1F1B with interleaved virtual stages.

Two coupled pieces:

1. **Schedule** (`one_f_one_b`, `bubble_count`, `idle_slots`): an explicit
   1F1B (one-forward-one-back) microbatch schedule, simulated per device
   with unit F/B slots. ``interleave=v`` extends it to Megatron-style
   interleaved virtual stages: the layer stack is cut into ``V = v*S``
   chunks and chunk ``c`` lives on device ``c % S``, so each device owns
   ``v`` depth-separated chunks and the warmup ramp is paid in 1/v-depth
   chunk units — the per-device bubble fraction shrinks from
   ``(S-1)/(M+S-1)`` toward ``(S-1)/(v*M+S-1)``
   (`simulated_bubble_fraction`). The simulator is the scheduling and
   accounting source of truth: `pipelined_value_and_grad` walks its slot
   list verbatim and reports the slots it executed, which
   tests/test_pipeline.py asserts equal to the simulator's counts.

2. **Execution** (`pipelined_value_and_grad`, `pipelined_loss`): the
   transformer block stack is partitioned into contiguous per-chunk layer
   ranges (embed pinned to chunk 0, head/loss to chunk V-1) and the
   schedule is executed tick by tick as an unrolled loop. The device
   dimension stays *vectorized* (leading S axis on activations and
   chunk-local params) and sharded over the ``pipe`` mesh axis, so GSPMD
   partitions each tick's chunk computation across pipe devices and lowers
   the inter-chunk activation/cotangent handoff — a shift of the device
   axis — to ``collective-permute`` (verified in the lowered HLO by
   tests/test_pipeline.py).

   **Memory model (the point of this formulation).** Each forward slot
   runs the chunk forward and keeps exactly one residual set per in-flight
   microbatch: the chunk's *input* activation. The backward slot for that
   (chunk, microbatch) re-runs the chunk forward under ``jax.vjp`` from
   the stored input (rematerialization) and applies the pullback, after
   which the residual is dead — the unrolled graph hands XLA's buffer
   liveness exactly the 1F1B lifetime, so peak activation memory is
   O(in-flight) = O(S) per device instead of the O(M) the previous
   AD-through-``lax.scan`` formulation paid (scan saved every tick's
   carry for the transposed replay, giving the 1F1B schedule with GPipe
   memory). `benchmarks/scaling_bench.py` measures this as the
   ``pp_peak_mem_M{4,8,16}`` rows: peak temp memory at fixed S is flat in
   M. Interleaving trades some of it back: v chunks per device hold up to
   ``S`` in-flight inputs *each* (the per-virtual-stage 1F1B cap), so
   interleaved peak memory is O(v*S) chunk inputs per device — still flat
   in M.

   Parameter gradients are accumulated across backward slots in fp32
   (each pullback cotangent is cast to f32 before the ``+= ct/M``), which
   is what makes ``cast_params_bf16`` legal under pp>1: the bf16 compute
   view flows through the chunk/head/embed VJPs while the accumulator —
   like ``accumulate_gradients``'s — stays f32. Per-microbatch PRNG keys
   (``rngs``) thread through ``microbatch_fn`` at every point a microbatch
   is materialized (stage-0 inject, head loss, embed backward), so
   on-device augmentation keyed by ``fold_in(state.rng, step)`` is
   resume-exact under pp, matching the dp path.

   Why not ``shard_map`` + ``jax.lax.ppermute``: manual collectives on a
   manual-subgroup axis combined with ``auto`` (GSPMD) axes hit an
   unimplemented path in the jaxlib 0.4.37 SPMD partitioner ("PartitionId
   instruction is not supported" / IsManualSubgroup check failure). The
   vectorized-device formulation produces the identical collective-permute
   schedule while keeping ZeRO / tensor-parallel sharding on the remaining
   axes fully composable; grads of chunk-local params stay pipe-sharded
   and reduce-scatter over dp exactly as in the non-pipelined path.

Engine knobs: ``EngineConfig.pipeline_stages`` (=S, the pipe-axis extent)
and ``EngineConfig.pipeline_interleave`` (=v, virtual chunks per device;
``launch/train.py --pp-interleave``). Interleaving requires
``num_layers % (S*v) == 0`` and ``num_micro % S == 0`` (the Megatron
grouping constraint).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.grad_accum import split_microbatches
from repro.models import transformer as model

PIPE_AXIS = "pipe"


# ---------------------------------------------------------------------------
# stage partitioning
# ---------------------------------------------------------------------------

def stage_partition(num_layers: int, stages: int) -> List[tuple]:
    """Contiguous [lo, hi) layer ranges per (virtual) stage; embed is pinned
    to chunk 0 and the head to the last chunk by construction."""
    if stages < 1:
        raise ValueError(f"stages must be >= 1, got {stages}")
    if num_layers % stages:
        raise ValueError(
            f"num_layers={num_layers} not divisible by pipeline "
            f"stages={stages}")
    lps = num_layers // stages
    return [(s * lps, (s + 1) * lps) for s in range(stages)]


def check_supported(cfg) -> None:
    """Pipeline path covers the scan-stacked attn/mla block stack (the
    paper's ViT + dense LMs). Branching stacks need per-stage routing."""
    if cfg.block_kind not in ("attn", "mla"):
        raise ValueError(
            f"pipeline_stages > 1 unsupported for block_kind="
            f"{cfg.block_kind!r} (only attn/mla stacks)")
    if cfg.moe and cfg.moe.num_experts > 0:
        raise ValueError("pipeline_stages > 1 unsupported for MoE stacks "
                         "(dense/moe split breaks contiguous staging)")
    if cfg.mtp_depth > 0:
        raise ValueError("pipeline_stages > 1 unsupported with MTP heads")
    if cfg.hybrid_group > 0:
        raise ValueError("pipeline_stages > 1 unsupported for hybrid stacks")
    if cfg.rope_style == "mrope" or cfg.arch_type == "vlm":
        # M-RoPE positions are batch-supplied per microbatch; the pipelined
        # loop computes positions once from microbatch 0 (valid only for
        # shape-derived arange/None positions), so vlm would silently train
        # with microbatch-0's position grid
        raise ValueError("pipeline_stages > 1 unsupported for vlm/M-RoPE "
                         "(batch-dependent rope positions)")


# ---------------------------------------------------------------------------
# 1F1B schedule (flat + interleaved)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PipeTask:
    kind: str       # "F" | "B"
    micro: int      # microbatch index
    chunk: int = 0  # virtual stage index in [0, stages * interleave)


def one_f_one_b(num_micro: int, num_stages: int, interleave: int = 1
                ) -> List[List[Optional[PipeTask]]]:
    """Simulate the 1F1B schedule with unit F/B slots.

    Returns ``sched[device][tick] -> PipeTask | None`` (None = bubble).
    ``interleave=1`` is the flat schedule: chunk == stage == device, warmup
    forwards, steady-state F/B alternation, cooldown backwards, per-stage
    in-flight cap ``num_stages - stage`` (DeepSpeed/PipeDream-flush).

    ``interleave=v > 1`` is the Megatron interleaved schedule over
    ``V = v * num_stages`` virtual stages, chunk ``c`` on device ``c % S``:
    each device issues forwards in groups of S microbatches cycling through
    its chunks shallow-to-deep (backwards deep-to-shallow), with warmup
    ``min(2*(S-d-1) + (v-1)*S, v*M)`` and strict 1F1B alternation after —
    falling back to the other slot kind only when the scheduled kind's
    dependency is not yet satisfied. In-flight residuals per device never
    exceed ``warmup_d + 1`` — flat in M (asserted here; the hypothesis
    suite in tests/test_pipeline.py re-checks it property-style, and the
    flat schedule keeps the strict ``<= S - d <= S`` cap).
    """
    S, M, v = num_stages, num_micro, interleave
    if v < 1:
        raise ValueError(f"interleave must be >= 1, got {v}")
    if M < S:
        raise ValueError(
            f"1F1B needs microbatches >= stages: {M} < {S}")
    if v == 1:
        return _flat_one_f_one_b(M, S)
    if M % S:
        raise ValueError(
            f"interleaved 1F1B needs num_micro divisible by stages "
            f"(Megatron grouping): {M} % {S} != 0")
    V = S * v
    total = v * M

    def orders(dev):
        chunks = [k * S + dev for k in range(v)]
        groups = [range(g * S, (g + 1) * S) for g in range(M // S)]
        fwd = [(c, m) for g in groups for c in chunks for m in g]
        bwd = [(c, m) for g in groups for c in reversed(chunks) for m in g]
        return fwd, bwd

    forder, border = zip(*(orders(d) for d in range(S)))
    warmup = [min(2 * (S - d - 1) + (v - 1) * S, total) for d in range(S)]
    fwd_done, bwd_done = {}, {}
    nf, nb = [0] * S, [0] * S
    sched: List[List[Optional[PipeTask]]] = [[] for _ in range(S)]
    t = 0
    while min(nb) < total:
        if t > 8 * (total + V):         # simulator safety net
            raise RuntimeError("interleaved 1F1B schedule did not converge")
        for d in range(S):
            def try_fwd():
                if nf[d] >= total:
                    return None
                c, m = forder[d][nf[d]]
                if c > 0 and not fwd_done.get((c - 1, m), t) < t:
                    return None
                fwd_done[(c, m)] = t
                nf[d] += 1
                # the memory invariant the executor's residual store
                # relies on: per-device in-flight chunk inputs stay under
                # the warmup depth + 1 — flat in M
                assert nf[d] - nb[d] <= warmup[d] + 1, (d, m, t)
                return PipeTask("F", m, c)

            def try_bwd():
                if nb[d] >= total:
                    return None
                c, m = border[d][nb[d]]
                ready = (fwd_done.get((c, m), t) < t if c == V - 1
                         else bwd_done.get((c + 1, m), t) < t)
                if not ready or not fwd_done.get((c, m), t) < t:
                    return None
                bwd_done[(c, m)] = t
                nb[d] += 1
                return PipeTask("B", m, c)

            want_fwd = nf[d] < warmup[d] or (
                nf[d] < total and nf[d] - warmup[d] == nb[d])
            task = (try_fwd() or try_bwd()) if want_fwd \
                else (try_bwd() or try_fwd())
            sched[d].append(task)
        t += 1
    return sched


def _flat_one_f_one_b(M: int, S: int) -> List[List[Optional[PipeTask]]]:
    fwd_done = [[None] * M for _ in range(S)]   # tick stage s forwarded m
    bwd_done = [[None] * M for _ in range(S)]
    nf = [0] * S                                # forwards issued per stage
    nb = [0] * S                                # backwards issued per stage
    sched: List[List[Optional[PipeTask]]] = [[] for _ in range(S)]
    t = 0
    while min(nb) < M:
        if t > 4 * (M + S):                     # simulator safety net
            raise RuntimeError("1F1B schedule did not converge")
        for s in range(S):
            can_fwd = nf[s] < M and (
                s == 0 or fwd_done[s - 1][nf[s]] is not None
                and fwd_done[s - 1][nf[s]] < t)
            can_bwd = nb[s] < nf[s] and (
                s == S - 1 or bwd_done[s + 1][nb[s]] is not None
                and bwd_done[s + 1][nb[s]] < t)
            in_flight = nf[s] - nb[s]
            # the 1F1B memory cap: at most S - s activations live on stage
            # s; past the cap the stage waits for a backward, never piles
            # up more forwards (what distinguishes 1F1B from GPipe)
            if can_bwd and (in_flight >= S - s or nf[s] == M):
                bwd_done[s][nb[s]] = t
                sched[s].append(PipeTask("B", nb[s], s))
                nb[s] += 1
            elif can_fwd and in_flight < S - s:
                fwd_done[s][nf[s]] = t
                sched[s].append(PipeTask("F", nf[s], s))
                nf[s] += 1
            elif can_bwd:
                bwd_done[s][nb[s]] = t
                sched[s].append(PipeTask("B", nb[s], s))
                nb[s] += 1
            else:
                sched[s].append(None)
        t += 1
    return sched


def idle_slots(sched: List[List[Optional[PipeTask]]], dev: int) -> int:
    """Raw idle slot count of ``dev`` over the whole schedule."""
    return sum(1 for task in sched[dev] if task is None)


def bubble_count(sched: List[List[Optional[PipeTask]]], stage: int) -> int:
    """Idle slots of ``stage`` in F+B pair units — ``stages - 1`` for the
    flat 1F1B (the warmup/cooldown ramp each stage pays once)."""
    return idle_slots(sched, stage) // 2


def makespan(sched: List[List[Optional[PipeTask]]]) -> int:
    """Schedule length in unit slots (all device rows are equal length).
    One interleaved slot is 1/interleave of a flat slot — normalize by
    ``interleave`` when comparing across v."""
    return len(sched[0])


def bubble_fraction(num_micro: int, num_stages: int) -> float:
    """Analytic flat-1F1B pipeline-bubble fraction (S-1)/(M+S-1)."""
    return (num_stages - 1) / (num_micro + num_stages - 1)


def simulated_bubble_fraction(num_micro: int, num_stages: int,
                              interleave: int = 1) -> float:
    """Worst-device bubble fraction read off the simulated schedule — the
    number `scaling_sweep.py`/`scaling_bench.py` record for interleaved
    layouts. Equals `bubble_fraction` at interleave=1 and approaches
    (S-1)/(v*M+S-1) for the interleaved schedule."""
    sched = one_f_one_b(num_micro, num_stages, interleave)
    return max(idle_slots(sched, d) for d in range(num_stages)) \
        / makespan(sched)


def schedule_accounting(num_micro: int, num_stages: int,
                        interleave: int = 1) -> dict:
    """Per-device slot counts of the simulated schedule — the reference the
    executed-schedule accounting is asserted against."""
    sched = one_f_one_b(num_micro, num_stages, interleave)
    return {
        "ticks": makespan(sched),
        "F": [sum(1 for x in sched[d] if x and x.kind == "F")
              for d in range(num_stages)],
        "B": [sum(1 for x in sched[d] if x and x.kind == "B")
              for d in range(num_stages)],
        "idle": [idle_slots(sched, d) for d in range(num_stages)],
    }


# ---------------------------------------------------------------------------
# staged execution
# ---------------------------------------------------------------------------

def _constrain(x, spec):
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except RuntimeError as e:
        # tolerate ONLY the no-mesh case (single-device semantics tests);
        # anything else (spec/rank mismatch under a live mesh) must surface
        # — silently unconstrained stage params replicate across pipe
        if "mesh" not in str(e).lower():
            raise
        return x


def stage_stack_specs(stack_specs, stages_axis=PIPE_AXIS):
    """(L, ...) stacked-param specs -> (S, v, L/(S*v), ...) chunk-local
    specs.

    The engine's param specs put ``pipe`` on the leading L axis; after the
    device-major reshape the leading axis is the device axis (still pipe)
    and the chunk-round / layers-within-chunk axes are unsharded. Inner
    (fsdp/tp) dims are preserved so ZeRO-3 stays chunk-locally sharded.
    """
    def one(spec):
        parts = tuple(spec)
        lead = parts[0] if parts else None
        if lead not in (stages_axis, None):
            lead = stages_axis
        return P(stages_axis if lead is not None else None, None, None,
                 *parts[1:])
    return jax.tree.map(one, stack_specs,
                        is_leaf=lambda s: isinstance(s, P))


def _device_major(x, S: int, v: int):
    """(L, ...) -> (S, v, L/(S*v), ...): lead axis = device, chunk
    ``c = k*S + d`` lands at [d, k] (Megatron round-robin placement)."""
    lpc = x.shape[0] // (S * v)
    return x.reshape((v, S, lpc) + x.shape[1:]).swapaxes(0, 1)


def _device_major_inverse(x):
    """(S, v, lpc, ...) -> (L, ...), inverse of `_device_major`."""
    S, v, lpc = x.shape[:3]
    return x.swapaxes(0, 1).reshape((S * v * lpc,) + x.shape[3:])


def _staged_pipeline(cfg, params, batch, *, stages, num_micro, interleave,
                     dp_axes, pipe_axis, stack_specs, rngs, microbatch_fn,
                     want_grads, schedule_out=None):
    """Shared schedule-driven executor. ``want_grads=False`` runs forward
    slots only (losses at last-chunk exits); ``want_grads=True`` adds the
    backward slots with rematerialized per-chunk VJPs and returns fp32 mean
    grads alongside (loss, metrics)."""
    check_supported(cfg)
    S, M, v = stages, num_micro, interleave
    V = S * v
    stage_partition(cfg.num_layers, V)          # validates divisibility
    sched = one_f_one_b(M, S, v)                # validates M vs S, M % S

    mbs = split_microbatches(batch, M)          # (M, B/M, ...) leaves
    stack = jax.tree.map(lambda x: _device_major(x, S, v), params["stack"])
    if pipe_axis is not None:
        if stack_specs is None:
            stack_specs = jax.tree.map(
                lambda x: P(pipe_axis, *(None,) * (x.ndim - 1)), stack)
        stack = jax.tree.map(_constrain, stack, stack_specs)
    windows = _device_major(
        jnp.asarray(cfg.layer_windows(), jnp.int32), S, v)

    def micro_batch(m):
        mb = jax.tree.map(lambda x: x[m], mbs)
        if microbatch_fn is not None:
            mb = microbatch_fn(mb, None if rngs is None else rngs[m])
        return mb

    mb0 = micro_batch(0)
    inject0, positions = model.embed(cfg, params, mb0)
    dp = tuple(dp_axes)
    state_spec = None
    if pipe_axis is not None:
        state_spec = P(pipe_axis, dp if dp else None,
                       *(None,) * (inject0.ndim - 1))
    zero_lane = jnp.zeros(inject0.shape, inject0.dtype)

    def chunk_fn(chunk_stack, chunk_windows, h):
        return model.stack_forward(cfg, chunk_stack, h, positions,
                                   chunk_windows)

    def head_loss(p, h, mb):
        logits = model.apply_head(cfg, p, h)
        return model.loss_from_logits(cfg, logits, mb)

    def select_chunks(tasks):
        """Per-device chunk-round selection for one pass. Uniform rounds
        (always true for v=1) keep a plain slice; mixed rounds gather."""
        rounds = [0 if task is None else task.chunk // S for task in tasks]
        if len(set(rounds)) == 1:
            sel = jax.tree.map(lambda p: p[:, rounds[0]], stack)
            win = windows[:, rounds[0]]
        else:
            ar, ridx = jnp.arange(S), jnp.asarray(rounds)
            sel = jax.tree.map(lambda p: p[ar, ridx], stack)
            win = windows[ar, ridx]
        return sel, win, rounds

    def assemble(entries, shift_src_lane, tail_fn, mask_dead=False):
        """Build an (S, B, ...) lane array from per-lane sources.

        ``entries[d]``: None (dead lane, value irrelevant), a jnp array
        (fresh value, e.g. the embed inject or the head cotangent), or
        ``(arr, lane)`` referencing a lane of an earlier pass array. When
        every referenced lane follows the neighbor-shift pattern
        (``lane == (d + shift_src_lane) % S`` of one shared array) the
        handoff is emitted as a single axis-shift — the op GSPMD lowers to
        the inter-device collective-permute. ``tail_fn(base)`` supplies
        the slot the shift vacates.

        ``mask_dead`` zeroes the dead lanes after a shift assembly —
        REQUIRED for cotangents: a stalled backward leaves a live
        cotangent in the previous pass array, and the shift would leak it
        into a dead lane whose pullback then pollutes the stack grads.
        (Forward activations skip it: dead-lane outputs are never stored.)
        """
        base, shift_ok = None, True
        for d, e in enumerate(entries):
            if not isinstance(e, tuple):
                continue
            arr, lane = e
            if lane != (d + shift_src_lane) % S:
                shift_ok = False
            if base is None:
                base = arr
            elif base is not arr:
                shift_ok = False
        fresh = [d for d, e in enumerate(entries)
                 if e is not None and not isinstance(e, tuple)]
        edge = 0 if shift_src_lane < 0 else S - 1
        if base is not None and shift_ok and all(d == edge for d in fresh):
            tail = entries[edge][None] if fresh else tail_fn(base)
            if shift_src_lane < 0:      # forward: lane d <- base[d-1]
                out = jnp.concatenate([tail, base[:-1]], 0)
            else:                       # backward: lane d <- base[d+1]
                out = jnp.concatenate([base[1:], tail], 0)
            dead = [d for d, e in enumerate(entries) if e is None]
            if mask_dead and dead:
                live = jnp.asarray(
                    [0.0 if d in dead else 1.0 for d in range(S)],
                    out.dtype).reshape((S,) + (1,) * (out.ndim - 1))
                out = out * live
            return out
        lanes = [zero_lane if e is None else (e[0][e[1]]
                 if isinstance(e, tuple) else e)
                 for e in entries]
        return jnp.stack(lanes, 0)

    inv_m = 1.0 / M
    loss_sum = jnp.float32(0.0)
    metric0 = jax.eval_shape(lambda: head_loss(params, inject0, mb0))[1]
    metric_sum = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), metric0)

    def acc_tree(acc, ct):
        # the fp32 accumulation policy shared with accumulate_gradients:
        # per-microbatch cotangents (possibly bf16 under cast_params_bf16)
        # cast up BEFORE the += ct/M
        return jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32) * inv_m, acc, ct)

    gacc = gstack = None
    if want_grads:
        gacc = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params)
        gstack = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              stack)

    act = {}    # (chunk, m) -> (pass array, lane): chunk output
    xin = {}    # (chunk, m) -> (pass array, lane): chunk input (residual)
    gst = {}    # (chunk, m) -> (pass array, lane): dL/d(chunk input)
    counts = {"F": [0] * S, "B": [0] * S, "idle": [0] * S}

    for t in range(makespan(sched)):
        ftasks, btasks = [], []
        for d in range(S):
            task = sched[d][t]
            if task is not None:
                assert task.chunk % S == d, (d, task)
                counts[task.kind][d] += 1
            else:
                counts["idle"][d] += 1
            ftasks.append(task if task and task.kind == "F" else None)
            btasks.append(task if task and task.kind == "B" else None)

        if any(t_ is not None for t_ in ftasks):
            entries = []
            for d, task in enumerate(ftasks):
                if task is None:
                    entries.append(None)
                elif task.chunk == 0:   # stage-0 inject (device 0 only)
                    entries.append(
                        model.embed(cfg, params,
                                    micro_batch(task.micro))[0])
                else:
                    entries.append(act.pop((task.chunk - 1, task.micro)))
            x = _constrain(
                assemble(entries, -1, lambda b: b[-1:]), state_spec)
            sel, win, rounds = select_chunks(ftasks)
            y = _constrain(jax.vmap(chunk_fn)(sel, win, x), state_spec)
            for d, task in enumerate(ftasks):
                if task is None:
                    continue
                act[(task.chunk, task.micro)] = (y, d)
                if want_grads:
                    xin[(task.chunk, task.micro)] = (x, d)
                elif task.chunk == V - 1:
                    # forward-only: microbatch exits the pipe here
                    _, lane = act.pop((task.chunk, task.micro))
                    loss_m, metrics_m = head_loss(
                        params, y[lane], micro_batch(task.micro))
                    loss_sum = loss_sum + loss_m
                    metric_sum = jax.tree.map(
                        lambda a, m_: a + m_, metric_sum, metrics_m)

        if want_grads and any(t_ is not None for t_ in btasks):
            xentries, gentries = [], []
            for d, task in enumerate(btasks):
                if task is None:
                    xentries.append(None)
                    gentries.append(None)
                    continue
                c, m = task.chunk, task.micro
                xentries.append(xin.pop((c, m)))
                if c == V - 1:
                    # head + loss VJP seeds the backward wavefront the
                    # slot the microbatch's forward exited (device S-1)
                    yarr, lane = act.pop((c, m))
                    loss_m, head_pb, metrics_m = jax.vjp(
                        lambda p, h, _m=m: head_loss(
                            p, h, micro_batch(_m)),
                        params, yarr[lane], has_aux=True)
                    p_ct, h_ct = head_pb(jnp.float32(1.0))
                    gacc = acc_tree(gacc, p_ct)
                    loss_sum = loss_sum + loss_m
                    metric_sum = jax.tree.map(
                        lambda a, m_: a + m_, metric_sum, metrics_m)
                    gentries.append(h_ct)
                else:
                    gentries.append(gst.pop((c + 1, m)))
            xb = _constrain(
                assemble(xentries, -1, lambda b: b[-1:]), state_spec)
            g = _constrain(
                assemble(gentries, 1, lambda b: b[:1], mask_dead=True),
                state_spec)
            sel, win, rounds = select_chunks(btasks)
            # rematerialized per-chunk VJP: re-run the chunk forward from
            # the stored inputs, pull the output cotangents back — the
            # stored input is the ONLY residual that outlived the forward
            _, chunk_pb = jax.vjp(
                lambda sk, xx: jax.vmap(chunk_fn)(sk, win, xx), sel, xb)
            sel_ct, x_ct = chunk_pb(g)
            if len(set(rounds)) == 1:
                gstack = jax.tree.map(
                    lambda a, g_: a.at[:, rounds[0]].add(
                        g_.astype(jnp.float32) * inv_m), gstack, sel_ct)
            else:
                ar, ridx = jnp.arange(S), jnp.asarray(rounds)
                gstack = jax.tree.map(
                    lambda a, g_: a.at[ar, ridx].add(
                        g_.astype(jnp.float32) * inv_m), gstack, sel_ct)
            for d, task in enumerate(btasks):
                if task is None:
                    continue
                c, m = task.chunk, task.micro
                if c == 0:
                    # cotangent reaches the inject: embed VJP (device 0)
                    _, emb_pb = jax.vjp(
                        lambda p, _m=m: model.embed(
                            cfg, p, micro_batch(_m))[0], params)
                    (p_ct,) = emb_pb(x_ct[d])
                    gacc = acc_tree(gacc, p_ct)
                else:
                    gst[(c, m)] = (x_ct, d)

    loss = loss_sum * inv_m
    metrics = jax.tree.map(lambda m_: m_ * inv_m, metric_sum)
    metrics["loss"] = loss
    if schedule_out is not None:
        schedule_out.update(schedule=sched, executed=counts,
                            ticks=makespan(sched))
    if not want_grads:
        assert not xin and not gst
        return loss, metrics
    assert not act and not xin and not gst, (act.keys(), xin.keys(),
                                             gst.keys())
    grads = {k: v_ for k, v_ in gacc.items()}
    grads["stack"] = jax.tree.map(
        lambda a, b: a + _device_major_inverse(b), gacc["stack"], gstack)
    return (loss, metrics), grads


def pipelined_loss(cfg, params, batch, *, stages: int, num_micro: int,
                   interleave: int = 1, dp_axes=("data",),
                   pipe_axis: Optional[str] = PIPE_AXIS, stack_specs=None,
                   rngs=None, microbatch_fn=None, schedule_out=None):
    """1F1B-scheduled pipeline-parallel loss: (loss, metrics).

    Forward slots of the simulated schedule only — microbatch losses are
    taken as each microbatch exits the last chunk, so the value matches
    ``pipelined_value_and_grad`` (and the dp path's
    ``accumulate_gradients`` over the same ``split_microbatches``) exactly.

    ``rngs`` is an optional (num_micro, ...) stack of per-microbatch PRNG
    keys handed to ``microbatch_fn(mb, rng)`` wherever a microbatch is
    materialized — the engine threads its augmentation/preprocess closure
    through here. ``pipe_axis=None`` drops sharding constraints
    (semantics-only mode used by single-device tests).

    Checkpoint note: the engine saves the UNRESHAPED ``params["stack"]``
    leaves — the (L, ...) layout with L sharded over ``pipe`` — so the
    elastic checkpoint layer sees plain sharded arrays. The device-major
    (S, v, L/(S*v), ...) view built here is a transient inside the step;
    restores into a different pp extent just re-slice the L axis via the
    target engine's specs, no pipeline-specific resharding logic needed.
    """
    return _staged_pipeline(
        cfg, params, batch, stages=stages, num_micro=num_micro,
        interleave=interleave, dp_axes=dp_axes, pipe_axis=pipe_axis,
        stack_specs=stack_specs, rngs=rngs, microbatch_fn=microbatch_fn,
        want_grads=False, schedule_out=schedule_out)


def pipelined_value_and_grad(cfg, params, batch, *, stages: int,
                             num_micro: int, interleave: int = 1,
                             dp_axes=("data",),
                             pipe_axis: Optional[str] = PIPE_AXIS,
                             stack_specs=None, rngs=None,
                             microbatch_fn=None, schedule_out=None):
    """((loss, metrics), grads) via manually-staged per-chunk VJPs on the
    1F1B schedule — the memory-bounded replacement for
    ``jax.value_and_grad(pipelined_loss)``.

    Numerically interchangeable with ``accumulate_gradients``: grads are
    the fp32 mean of per-microbatch grads (each pullback cotangent is cast
    to f32 before accumulation — the policy that makes
    ``cast_params_bf16`` legal under pp), the loss is the mean of
    per-microbatch losses, and metrics are microbatch means. Peak
    activation memory is O(S) per-chunk input residuals per device
    (O(v*S) interleaved) instead of the old scan path's O(M) — see the
    module docstring's memory model.
    """
    return _staged_pipeline(
        cfg, params, batch, stages=stages, num_micro=num_micro,
        interleave=interleave, dp_axes=dp_axes, pipe_axis=pipe_axis,
        stack_specs=stack_specs, rngs=rngs, microbatch_fn=microbatch_fn,
        want_grads=True, schedule_out=schedule_out)
