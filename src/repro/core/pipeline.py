"""Pipeline parallelism (DeepSpeed PipelineEngine equivalent) on a `pipe`
mesh axis.

Two coupled pieces:

1. **Schedule** (`one_f_one_b`, `bubble_count`): an explicit 1F1B
   (one-forward-one-back) microbatch schedule, simulated per stage with unit
   forward/backward slots — warmup forwards, steady-state F/B alternation,
   cooldown backwards. This is the scheduling/accounting source of truth:
   per-stage bubble count is ``stages - 1`` slot pairs and the bubble
   fraction is ``(S-1)/(M+S-1)``, which `benchmarks/scaling_bench.py`
   records next to measured step times.

2. **Execution** (`pipelined_loss`): the transformer block stack is
   partitioned into contiguous per-stage layer ranges (embed pinned to the
   first stage, head/loss to the last), and the microbatch loop runs as a
   ``jax.lax.scan`` over ``M + S - 1`` pipeline ticks. The stage dimension is
   *vectorized* (leading S axis on activations and stage-local params) and
   sharded over the ``pipe`` mesh axis, so GSPMD partitions each tick's
   stage computation across pipe devices and lowers the end-of-tick shift
   ``concat([inject, h[:-1]])`` to the inter-stage ``collective-permute``
   (verified in the lowered HLO by tests/test_pipeline.py). Reverse-mode AD
   through the scan transposes the shift and replays the ticks backwards —
   the backward pipeline with the same per-stage bubble structure.

   Why not ``shard_map`` + ``jax.lax.ppermute``: manual collectives on a
   manual-subgroup axis combined with ``auto`` (GSPMD) axes hit an
   unimplemented path in the jaxlib 0.4.37 SPMD partitioner ("PartitionId
   instruction is not supported" / IsManualSubgroup check failure). The
   vectorized-stage formulation produces the identical collective-permute
   schedule while keeping ZeRO / tensor-parallel sharding on the remaining
   axes fully composable (the issue's requirement); grads of stage-local
   params stay pipe-sharded and reduce-scatter over dp exactly as in the
   non-pipelined path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.grad_accum import split_microbatches
from repro.models import transformer as model

PIPE_AXIS = "pipe"


# ---------------------------------------------------------------------------
# stage partitioning
# ---------------------------------------------------------------------------

def stage_partition(num_layers: int, stages: int) -> List[tuple]:
    """Contiguous [lo, hi) layer ranges per stage; embed is pinned to stage
    0 and the head to stage ``stages - 1`` by construction."""
    if stages < 1:
        raise ValueError(f"stages must be >= 1, got {stages}")
    if num_layers % stages:
        raise ValueError(
            f"num_layers={num_layers} not divisible by pipeline "
            f"stages={stages}")
    lps = num_layers // stages
    return [(s * lps, (s + 1) * lps) for s in range(stages)]


def check_supported(cfg) -> None:
    """Pipeline path covers the scan-stacked attn/mla block stack (the
    paper's ViT + dense LMs). Branching stacks need per-stage routing."""
    if cfg.block_kind not in ("attn", "mla"):
        raise ValueError(
            f"pipeline_stages > 1 unsupported for block_kind="
            f"{cfg.block_kind!r} (only attn/mla stacks)")
    if cfg.moe and cfg.moe.num_experts > 0:
        raise ValueError("pipeline_stages > 1 unsupported for MoE stacks "
                         "(dense/moe split breaks contiguous staging)")
    if cfg.mtp_depth > 0:
        raise ValueError("pipeline_stages > 1 unsupported with MTP heads")
    if cfg.hybrid_group > 0:
        raise ValueError("pipeline_stages > 1 unsupported for hybrid stacks")
    if cfg.rope_style == "mrope" or cfg.arch_type == "vlm":
        # M-RoPE positions are batch-supplied per microbatch; the pipelined
        # loop computes positions once from microbatch 0 (valid only for
        # shape-derived arange/None positions), so vlm would silently train
        # with microbatch-0's position grid
        raise ValueError("pipeline_stages > 1 unsupported for vlm/M-RoPE "
                         "(batch-dependent rope positions)")


# ---------------------------------------------------------------------------
# 1F1B schedule
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PipeTask:
    kind: str       # "F" | "B"
    micro: int      # microbatch index


def one_f_one_b(num_micro: int, num_stages: int) -> List[List[Optional[PipeTask]]]:
    """Simulate the 1F1B schedule with unit F/B slots.

    Returns ``sched[stage][tick] -> PipeTask | None`` (None = bubble).
    Dependency rules: stage s may forward microbatch m one tick after stage
    s-1 forwarded it; may backward m one tick after stage s+1 backwarded it
    (last stage: after its own forward). Policy: each stage caps in-flight
    microbatches at ``num_stages - stage`` — warmup forwards, then strict
    F/B alternation, then cooldown backwards (DeepSpeed/PipeDream-flush).
    """
    if num_micro < num_stages:
        raise ValueError(
            f"1F1B needs microbatches >= stages: {num_micro} < {num_stages}")
    S, M = num_stages, num_micro
    fwd_done = [[None] * M for _ in range(S)]   # tick stage s forwarded m
    bwd_done = [[None] * M for _ in range(S)]
    nf = [0] * S                                # forwards issued per stage
    nb = [0] * S                                # backwards issued per stage
    sched: List[List[Optional[PipeTask]]] = [[] for _ in range(S)]
    t = 0
    while min(nb) < M:
        if t > 4 * (M + S):                     # simulator safety net
            raise RuntimeError("1F1B schedule did not converge")
        for s in range(S):
            can_fwd = nf[s] < M and (
                s == 0 or fwd_done[s - 1][nf[s]] is not None
                and fwd_done[s - 1][nf[s]] < t)
            can_bwd = nb[s] < nf[s] and (
                s == S - 1 or bwd_done[s + 1][nb[s]] is not None
                and bwd_done[s + 1][nb[s]] < t)
            in_flight = nf[s] - nb[s]
            # the 1F1B memory cap: at most S - s activations live on stage
            # s; past the cap the stage waits for a backward, never piles
            # up more forwards (what distinguishes 1F1B from GPipe)
            if can_bwd and (in_flight >= S - s or nf[s] == M):
                bwd_done[s][nb[s]] = t
                sched[s].append(PipeTask("B", nb[s]))
                nb[s] += 1
            elif can_fwd and in_flight < S - s:
                fwd_done[s][nf[s]] = t
                sched[s].append(PipeTask("F", nf[s]))
                nf[s] += 1
            elif can_bwd:
                bwd_done[s][nb[s]] = t
                sched[s].append(PipeTask("B", nb[s]))
                nb[s] += 1
            else:
                sched[s].append(None)
        t += 1
    return sched


def bubble_count(sched: List[List[Optional[PipeTask]]], stage: int) -> int:
    """Idle slots of ``stage`` in F+B pair units — ``stages - 1`` for 1F1B
    (the warmup/cooldown ramp each stage pays once)."""
    idle = sum(1 for task in sched[stage] if task is None)
    assert idle % 2 == 0, (stage, idle)
    return idle // 2


def bubble_fraction(num_micro: int, num_stages: int) -> float:
    """Analytic pipeline-bubble fraction (S-1)/(M+S-1) of the 1F1B round."""
    return (num_stages - 1) / (num_micro + num_stages - 1)


# ---------------------------------------------------------------------------
# pipelined execution
# ---------------------------------------------------------------------------

def _constrain(x, spec):
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except RuntimeError as e:
        # tolerate ONLY the no-mesh case (single-device semantics tests);
        # anything else (spec/rank mismatch under a live mesh) must surface
        # — silently unconstrained stage params replicate across pipe
        if "mesh" not in str(e).lower():
            raise
        return x


def stage_stack_specs(stack_specs, stages_axis=PIPE_AXIS):
    """(L, ...) stacked-param specs -> (S, L/S, ...) stage-local specs.

    The engine's param specs put ``pipe`` on the leading L axis; after the
    per-stage reshape the leading axis is the stage axis (still pipe) and
    the layers-within-stage axis is unsharded. Inner (fsdp/tp) dims are
    preserved so ZeRO-3 stays stage-locally sharded.
    """
    def one(spec):
        parts = tuple(spec)
        lead = parts[0] if parts else None
        if lead not in (stages_axis, None):
            lead = stages_axis
        return P(stages_axis if lead is not None else None, None,
                 *parts[1:])
    return jax.tree.map(one, stack_specs,
                        is_leaf=lambda s: isinstance(s, P))


def pipelined_loss(cfg, params, batch, *, stages: int, num_micro: int,
                   dp_axes=("data",), pipe_axis: Optional[str] = PIPE_AXIS,
                   stack_specs=None, rngs=None):
    """1F1B-scheduled pipeline-parallel loss: (loss, metrics).

    Matches ``accumulate_gradients(model.loss_fn, ...)`` numerically —
    microbatches come from the same ``split_microbatches``, the loss is the
    mean of per-microbatch losses, and metrics are microbatch means — so
    pp>1 reproduces the dp-only loss trajectory (tests/test_pipeline.py).

    ``pipe_axis=None`` drops sharding constraints (semantics-only mode used
    by single-device tests); ``stack_specs`` optionally carries the engine's
    stage-local specs so ZeRO inner-dim sharding survives the reshape.

    ``rngs`` exists for signature parity with ``accumulate_gradients`` but
    must be None: the AD-through-scan pipeline re-derives each microbatch at
    several ticks, so per-microbatch stochastic regularization would need
    per-tick rng plumbing that does not exist yet.

    Checkpoint note: the engine saves the UNRESHAPED ``params["stack"]``
    leaves — the (L, ...) layout with L sharded over ``pipe`` — so the
    elastic checkpoint layer sees plain sharded arrays. The per-stage
    (S, L/S, ...) view built here is a transient inside the step; restores
    into a different pp extent just re-slice the L axis via the target
    engine's specs, no pipeline-specific resharding logic needed.
    """
    if rngs is not None:
        raise ValueError(
            "pipelined_loss does not support per-microbatch rngs "
            "(AD-through-scan replays microbatches across ticks; stochastic "
            "regularization needs per-tick rng plumbing)")
    check_supported(cfg)
    stage_partition(cfg.num_layers, stages)     # validates divisibility
    S, M = stages, num_micro
    if M < S:
        raise ValueError(f"1F1B needs microbatches >= stages: {M} < {S}")

    mbs = split_microbatches(batch, M)          # (M, B/M, ...) leaves
    lps = cfg.num_layers // S
    stack = jax.tree.map(
        lambda x: x.reshape((S, lps) + x.shape[1:]), params["stack"])
    if pipe_axis is not None:
        if stack_specs is None:
            stack_specs = jax.tree.map(
                lambda x: P(pipe_axis, *(None,) * (x.ndim - 1)), stack)
        stack = jax.tree.map(_constrain, stack, stack_specs)
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32).reshape(S, lps)

    mb0 = jax.tree.map(lambda x: x[0], mbs)
    inject0, positions = model.embed(cfg, params, mb0)
    dp = tuple(dp_axes)
    state_spec = None
    if pipe_axis is not None:
        state_spec = P(pipe_axis, dp if dp else None,
                       *(None,) * (inject0.ndim - 1))

    def stage_fn(stage_stack, stage_windows, h):
        return model.stack_forward(cfg, stage_stack, h, positions,
                                   stage_windows)

    def tick(carry, t):
        h_out, loss_sum, metric_sum = carry
        # stage 0 ingests microbatch t (clamped: ticks >= M drain the pipe
        # with a dead re-injection whose output never reaches the head)
        mb = jax.tree.map(lambda x: x[jnp.minimum(t, M - 1)], mbs)
        inject, _ = model.embed(cfg, params, mb)
        # inter-stage transfer: shift the stage axis by one — GSPMD lowers
        # this to collective-permute over `pipe`
        x_in = _constrain(jnp.concatenate([inject[None], h_out[:-1]], 0),
                          state_spec)
        h_new = _constrain(jax.vmap(stage_fn)(stack, windows, x_in),
                           state_spec)
        # last stage: microbatch t-(S-1) exits the pipe this tick
        m_idx = t - (S - 1)
        mb_out = jax.tree.map(lambda x: x[jnp.maximum(m_idx, 0)], mbs)
        logits = model.apply_head(cfg, params, h_new[-1])
        loss, metrics = model.loss_from_logits(cfg, logits, mb_out)
        valid = t >= S - 1
        loss_sum = loss_sum + jnp.where(valid, loss, 0.0)
        metric_sum = jax.tree.map(
            lambda a, m: a + jnp.where(valid, m, jnp.zeros_like(m)),
            metric_sum, metrics)
        return (h_new, loss_sum, metric_sum), None

    h0 = _constrain(jnp.zeros((S,) + inject0.shape, inject0.dtype),
                    state_spec)
    metric0 = jax.eval_shape(
        lambda: model.loss_from_logits(
            cfg, model.apply_head(cfg, params, inject0), mb0))[1]
    metric0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), metric0)
    (_, loss_sum, metric_sum), _ = jax.lax.scan(
        tick, (h0, jnp.float32(0.0), metric0),
        jnp.arange(M + S - 1, dtype=jnp.int32))
    loss = loss_sum / M
    metrics = jax.tree.map(lambda m: m / M, metric_sum)
    metrics["loss"] = loss
    return loss, metrics
