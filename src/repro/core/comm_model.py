"""Analytic communication/compute cost model — the 'cluster simulator'.

The paper measures wall-clock sync overhead on real GPU clusters (Figs. 4-6,
14-15); this container has one CPU, so scaling curves are *modeled*: measured
single-device compute time × an analytic collective model, with hardware
constants for the TPU v5e target (and the paper's clusters, for the
heterogeneous Tesla reproduction).

Ring all-reduce time:  t = 2 (n-1)/n * bytes / bw   (+ per-hop latency)
Hierarchical (multi-pod): reduce-scatter intra-pod (ICI) -> all-reduce
across pods (DCN) -> all-gather intra-pod.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence


@dataclass(frozen=True)
class Hardware:
    name: str = "tpu_v5e"
    peak_flops: float = 197e12        # bf16 / chip
    hbm_bw: float = 819e9             # B/s
    ici_bw: float = 50e9              # B/s per link
    dcn_bw: float = 6.25e9            # B/s per chip, inter-pod
    link_latency: float = 1e-6        # s per hop
    host_infeed_bw: float = 10e9      # B/s host->HBM (paper's Fig6 plateau)


TPU_V5E = Hardware()

# The paper's clusters (§III Fig.3), fp32 GEMM throughput estimates.
GPU_SPECS = {
    "rtx3070": 20.3e12, "gtx1070": 6.5e12, "tesla_p4": 5.5e12,
    "t4": 8.1e12, "rtx2080ti": 13.4e12,
}


def allreduce_time(nbytes: float, n: int, bw: float,
                   latency: float = 1e-6) -> float:
    if n <= 1:
        return 0.0
    return 2.0 * (n - 1) / n * nbytes / bw + 2 * (n - 1) * latency


def reduce_scatter_time(nbytes: float, n: int, bw: float,
                        latency: float = 1e-6) -> float:
    if n <= 1:
        return 0.0
    return (n - 1) / n * nbytes / bw + (n - 1) * latency


def allgather_time(nbytes: float, n: int, bw: float,
                   latency: float = 1e-6) -> float:
    return reduce_scatter_time(nbytes, n, bw, latency)


def hierarchical_allreduce_time(nbytes: float, intra: int, pods: int,
                                hw: Hardware = TPU_V5E) -> float:
    """reduce-scatter (ICI) -> cross-pod all-reduce (DCN) -> all-gather."""
    t = reduce_scatter_time(nbytes, intra, hw.ici_bw, hw.link_latency)
    t += allreduce_time(nbytes / max(intra, 1), pods, hw.dcn_bw, 50e-6)
    t += allgather_time(nbytes, intra, hw.ici_bw, hw.link_latency)
    return t


@dataclass
class StepModel:
    """DeepSpeed-style data-parallel step time model.

    compute_times: per-device per-microbatch fwd+bwd seconds — heterogeneous
    clusters (the paper's Tesla setup) pass unequal values; the step
    synchronizes on the slowest device (the paper's §IV-B observation).
    """
    grad_bytes: float
    compute_times: Sequence[float] = field(default_factory=lambda: [1.0])
    comm_bw: float = TPU_V5E.ici_bw
    latency: float = 1e-6
    accum_steps: int = 1
    infeed_bytes_per_mb: float = 0.0
    infeed_bw: float = TPU_V5E.host_infeed_bw

    def step_time(self) -> float:
        n = len(self.compute_times)
        compute = max(self.compute_times) * self.accum_steps
        infeed = self.infeed_bytes_per_mb * self.accum_steps / self.infeed_bw
        sync = allreduce_time(self.grad_bytes, n, self.comm_bw, self.latency)
        return compute + max(infeed - compute, 0.0) + sync

    def sync_fraction(self) -> float:
        n = len(self.compute_times)
        sync = allreduce_time(self.grad_bytes, n, self.comm_bw, self.latency)
        return sync / self.step_time()


def strong_scaling_times(single_dev_time: float, grad_bytes: float,
                         device_counts: Sequence[int],
                         comm_bw: float = TPU_V5E.ici_bw,
                         hetero: Sequence[float] | None = None):
    """Fixed global workload split across n devices (paper Figs. 4, 8, 14).
    hetero: optional per-device relative speeds (1.0 = reference)."""
    out = []
    for n in device_counts:
        speeds = (hetero or [1.0] * n)[:n]
        per_dev = [single_dev_time / n / s for s in speeds]
        m = StepModel(grad_bytes=grad_bytes, compute_times=per_dev,
                      comm_bw=comm_bw)
        out.append(m.step_time())
    return out


def weak_scaling_times(single_dev_time: float, grad_bytes: float,
                       device_counts: Sequence[int],
                       comm_bw: float = TPU_V5E.ici_bw,
                       hetero: Sequence[float] | None = None):
    """Per-device workload fixed (paper Figs. 5, 9, 17)."""
    out = []
    for n in device_counts:
        speeds = (hetero or [1.0] * n)[:n]
        per_dev = [single_dev_time / s for s in speeds]
        m = StepModel(grad_bytes=grad_bytes, compute_times=per_dev,
                      comm_bw=comm_bw)
        out.append(m.step_time())
    return out
