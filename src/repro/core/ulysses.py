"""DeepSpeed-Ulysses sequence parallelism, TPU-native (the paper's §V
future-work item, built as a first-class feature).

Ulysses [arXiv:2309.14509] shards the *sequence* dimension across workers
between blocks and all_to_all-reshards to *head* sharding inside attention.
On TPU we express the same schedule as GSPMD sharding constraints
(models/shardctx.py): activations constrained S-sharded on the `model` axis,
q/k/v constrained H-sharded inside attention — the compiler lowers the
reshard pair to the identical all_to_all collectives. The paper proposed
partitioning ViTs "along the image-patches dimension"; for the assigned LLM
architectures the patch dimension *is* the sequence dimension.
"""
from __future__ import annotations

from jax.sharding import PartitionSpec as P

from repro.models.shardctx import ShardHints


def make_hints(mesh, cfg=None, *, sequence_parallel: str = "none",
               tp_axis: str = "model", expert_parallel: bool = True):
    """Build activation-sharding hints, honoring head divisibility.

    Padded KV-head shardings (e.g. gemma3 kv=8 on a 16-way model axis) make
    GSPMD re-gather K/V inside every attention k-block iteration — a
    multi-TB/step collective storm found in §Perf round 2. Queries tolerate
    padding fine (round 4: a sequence-sharded-q fallback regressed qwen2.5
    prefill 8x and was reverted). Decision:
      q:  head sharding always (padded when q-heads don't divide)
      kv: head sharding when divisible, else replicated over model
    """
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = dp[0] if len(dp) == 1 else dp
    ep = tp_axis if (expert_parallel and tp_axis in mesh.axis_names) else None
    tp_ext = dict(zip(mesh.axis_names, mesh.devices.shape)).get(tp_axis, 1)
    q_ok = cfg is None or cfg.num_heads % tp_ext == 0
    kv_ok = cfg is None or (cfg.num_kv_heads % tp_ext == 0
                            and cfg.num_kv_heads > 0)

    attn_kv = P(dp, None, tp_axis, None) if kv_ok else P(dp, None, None,
                                                         None)
    attn_q = P(dp, None, tp_axis, None)
    attn_out = P(dp, None, tp_axis, None)

    if sequence_parallel == "ulysses" and q_ok and kv_ok:
        return ShardHints(
            act=P(dp, tp_axis, None),             # (B, S, D): S sharded
            attn_q=P(dp, None, tp_axis, None),    # inside attn: H sharded
            attn_kv=P(dp, None, tp_axis, None),
            attn_seq=P(dp, tp_axis, None, None),  # back to S sharded
            expert=ep,
        )
    return ShardHints(
        act=P(dp, None, None),
        attn_q=attn_q,
        attn_kv=attn_kv,
        attn_seq=attn_out,
        expert=ep,
    )
