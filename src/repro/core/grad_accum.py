"""Gradient accumulation — DeepSpeed's gradient_accumulation_steps semantics
as a jit-able lax.scan over micro-batches, fp32 accumulators."""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp


def split_microbatches(batch, accum: int):
    """(B, ...) leaves -> (accum, B/accum, ...)."""
    def split(x):
        if x.ndim == 0:
            return jnp.broadcast_to(x, (accum,))
        b = x.shape[0]
        assert b % accum == 0, (b, accum)
        return x.reshape((accum, b // accum) + x.shape[1:])
    return jax.tree.map(split, batch)


_warned_no_mesh = False


def _constrain_tree(tree, specs):
    """with_sharding_constraint over a pytree, tolerating ONLY the no-mesh
    case (single-device unit tests trace without a mesh context).

    Any other constraint failure is re-raised: silently dropping the
    dp-sharded accumulator spec would silently disable ZeRO-2's per-microstep
    reduce-scatter — the step would still be correct but replicate gradients,
    which is exactly the regression the spec exists to prevent.
    """
    if specs is None:
        return tree
    import jax.lax as lax

    def con(x, s):
        global _warned_no_mesh
        try:
            return lax.with_sharding_constraint(x, s)
        except RuntimeError as e:
            if "mesh" not in str(e).lower():
                raise
            if not _warned_no_mesh:
                _warned_no_mesh = True
                warnings.warn(
                    "grad_accum: sharding specs ignored — no mesh installed "
                    "at trace time, so the ZeRO-2 reduce-scatter constraint "
                    "is disabled (expected only in single-device tests): "
                    f"{e}", RuntimeWarning, stacklevel=3)
            return x
    return jax.tree.map(con, tree, specs)


def accumulate_gradients(loss_fn, params, batch, accum: int,
                         grad_specs=None, rngs=None):
    """loss_fn(params, microbatch) -> (loss, metrics).

    Returns (mean grads fp32, mean metrics). One fwd+bwd per micro-batch,
    sequential scan — gradients averaged, exactly DeepSpeed's
    micro_batch_per_gpu × gradient_accumulation_steps contract.

    grad_specs (§Perf / ZeRO-2 semantics): PartitionSpec tree for the fp32
    accumulator. Constraining it dp-sharded makes GSPMD REDUCE-SCATTER each
    micro-step's gradients into a 1/dp-sized carry instead of all-reducing
    into a replicated one — this is exactly DeepSpeed ZeRO stage 2.

    rngs: optional ``(accum, ...)`` stack of per-microbatch PRNG keys; when
    given, ``loss_fn`` is called as ``loss_fn(params, mb, rng)`` with its
    microbatch's key (the TrainState rng plumbing — the engine derives the
    stack from ``fold_in(state.rng, state.step)``, so the same microbatch
    always sees the same key, resumed or not). Deterministic losses that
    ignore the key cost nothing: XLA dead-code-eliminates the stream.
    """
    if rngs is None:
        def fn(p, mb, rng):
            del rng
            return loss_fn(p, mb)
        rngs = jnp.zeros((accum, 1), jnp.uint32)    # placeholder, DCE'd
    else:
        fn = loss_fn
    grad_fn = jax.value_and_grad(fn, has_aux=True)

    if accum == 1:
        (loss, metrics), grads = grad_fn(params, batch, rngs[0])
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        return _constrain_tree(grads, grad_specs), metrics

    mbs = split_microbatches(batch, accum)

    def body(acc, mb_rng):
        mb, rng = mb_rng
        (loss, metrics), grads = grad_fn(params, mb, rng)
        acc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32) / accum, acc, grads)
        return _constrain_tree(acc, grad_specs), metrics

    zero = _constrain_tree(
        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        grad_specs)
    grads, metrics = jax.lax.scan(body, zero, (mbs, rngs))
    metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics)
    return grads, metrics
