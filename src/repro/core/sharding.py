"""Sharding policy: DeepSpeed stages mapped to GSPMD PartitionSpecs.

ZeRO semantics on TPU (DESIGN.md §3): stages are expressed as sharding specs
rather than manual bucketing —

  stage 0 (paper-faithful DDP): params + opt state replicated over the dp
      axes; GSPMD inserts the gradient all-reduce the paper measures.
  stage 1: optimizer state sharded over dp, params replicated.
  stage 2: stage 1 + gradients reduce-scattered (GSPMD does this
      automatically once the *consumer* — the opt update — is dp-sharded).
  stage 3 (FSDP): parameters themselves sharded over dp; per-layer
      all-gather on use.

Tensor parallelism (Megatron column/row) over the `model` axis and expert
parallelism for MoE compose orthogonally.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# rule table: (path regex, spec builder). `fsdp` is the dp axis (or None),
# `tp` the model axis (or None). Specs are for the UNSTACKED leaf; a leading
# None is prepended for scan-stacked leaves (leading L axis).
# ---------------------------------------------------------------------------

def _rules(fsdp, tp, embed_sharding="vocab"):
    return [
        # --- MoE experts (leading E axis -> expert parallel over tp) ---
        (r"experts/w_(gate|up)$", P(tp, fsdp, None)),
        (r"experts/w_out$", P(tp, None, fsdp)),
        (r"/router$", P(fsdp, None)),
        # --- attention projections (Megatron col/row) ---
        (r"attn/w?[qkvg]$|attn/w_(uq|uk|uv)$", P(fsdp, tp)),
        (r"attn/(wo|w_o)$", P(tp, fsdp)),
        (r"attn/w_(dq|dkv)$", P(fsdp, None)),
        (r"attn/b[qkv]$", P(tp)),
        # --- dense mlp ---
        (r"mlp/w_(gate|up)$|shared/w_(gate|up)$", P(fsdp, tp)),
        (r"mlp/w_out$|shared/w_out$", P(tp, fsdp)),
        (r"mlp/b_up$", P(tp)),
        # --- mamba2 ---
        (r"mamba/w_in$", P(fsdp, tp)),
        (r"mamba/w_out$", P(tp, fsdp)),
        (r"mamba/conv_w$", P(None, tp)),
        (r"mamba/conv_b$", P(tp)),
        # --- rwkv6 ---
        (r"time_mix/w_[rkvg]$", P(fsdp, tp)),
        (r"time_mix/w_o$", P(tp, fsdp)),
        (r"time_mix/decay_w1$", P(fsdp, None)),
        (r"time_mix/decay_w2$", P(None, tp)),
        (r"time_mix/lora_w1$", P(fsdp, None)),
        (r"time_mix/lora_w2$", P(None, None, fsdp)),
        (r"time_mix/(ln_scale|ln_bias|decay_base)$", P(tp)),
        (r"time_mix/bonus_u$", P(tp, None)),
        (r"channel_mix/w_[k]$", P(fsdp, tp)),
        (r"channel_mix/w_v$", P(tp, fsdp)),
        (r"channel_mix/w_r$", P(fsdp, tp)),
        # --- embeddings / head ---
        # "vocab": Megatron-style vocab-parallel (gather needs masking —
        # XLA SPMD falls back to full remat; see §Perf). "dmodel": shard the
        # feature dim instead; the token gather is then shard-local.
        (r"embed/tok$", P(tp, fsdp) if embed_sharding == "vocab"
         else P(None, tp)),
        (r"head/w$", P(fsdp, tp)),
        (r"embed/(patch_w|feat_proj)$", P(None, fsdp)),
        (r"embed/pos$", P(None, fsdp)),
        # --- mtp projection ---
        (r"mtp/proj$", P(fsdp, None)),
    ]


_STACKED = re.compile(r"(^|/)(stack|dense_stack|moe_stack)(/|$)")


def _keystr(path):
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _divisible(dim: int, axes, mesh_shape) -> bool:
    if axes is None:
        return True
    names = axes if isinstance(axes, tuple) else (axes,)
    extent = int(np.prod([mesh_shape[a] for a in names]))
    return dim % extent == 0


def _sanitize(spec: P, shape, mesh_shape) -> P:
    """Drop sharding on any dim the mesh extent doesn't divide (GSPMD would
    pad; we prefer predictable layouts and report it instead)."""
    out = []
    for i, ax in enumerate(spec):
        out.append(ax if ax is not None
                   and _divisible(shape[i], ax, mesh_shape) else None)
    return P(*out)


def param_specs(params, *, zero_stage: int, tensor_parallel: bool,
                mesh, dp_axes=("data",), tp_axis: Optional[str] = "model",
                for_opt_state: bool = False, embed_sharding: str = "vocab",
                pipeline_axis: Optional[str] = None):
    """PartitionSpec pytree matching ``params``.

    for_opt_state: ZeRO-1/2 shard the *optimizer state* even when params are
    replicated (stage < 3).

    pipeline_axis: stage-local placement for pipeline parallelism — stacked
    leaves (leading L layer axis) shard that axis over the pipe axis, so each
    stage holds only its contiguous layer range (and ZeRO opt-state/grad
    specs become stage-local too). Non-stacked leaves (embed/head/norms) stay
    unmentioned on pipe, i.e. replicated across stages; only the first/last
    stage contributes their gradients.
    """
    shard_params = zero_stage >= 3 or for_opt_state and zero_stage >= 1
    fsdp = tuple(dp_axes) if shard_params else None
    if fsdp is not None and len(fsdp) == 1:
        fsdp = fsdp[0]
    tp = tp_axis if tensor_parallel else None
    rules = _rules(fsdp, tp, embed_sharding)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    lead = pipeline_axis if pipeline_axis in mesh.axis_names else None

    def spec_one(path, leaf):
        ks = _keystr(path)
        stacked = bool(_STACKED.search(ks))
        base = None
        for pat, spec in rules:
            if re.search(pat, ks):
                base = spec
                break
        if base is None:
            # norms, scalars, small vectors: shard over fsdp if it divides
            base = P(fsdp) if leaf.ndim >= 1 and not stacked else P()
            if stacked:
                base = P(lead, fsdp) if leaf.ndim >= 2 else P(lead)
            ndim_expected = leaf.ndim
            base = P(*(tuple(base) + (None,) * (ndim_expected - len(base))))
            return _sanitize(base, leaf.shape, mesh_shape)
        if stacked:
            base = P(*((lead,) + tuple(base)))
        # pad to leaf ndim
        base = P(*(tuple(base) + (None,) * (leaf.ndim - len(base))))
        return _sanitize(base, leaf.shape, mesh_shape)

    return jax.tree_util.tree_map_with_path(spec_one, params)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def spec_to_lists(spec) -> list:
    """JSON-serializable PartitionSpec: each dim None | "axis" |
    ["axis", ...] — the manifest encoding the elastic checkpoint layer
    records. Restore reshards against the TARGET engine's specs, so this
    is provenance/accounting metadata, not a restore input."""
    out = []
    for ax in spec:
        if ax is None:
            out.append(None)
        elif isinstance(ax, (tuple, list)):
            out.append([str(a) for a in ax])
        else:
            out.append(str(ax))
    return out


def describe_sharding(x) -> Optional[dict]:
    """Portable description of a jax.Array's sharding for the checkpoint
    manifest: the PartitionSpec it lives under plus the mesh axis extents
    (None for single-device / spec-less shardings)."""
    s = getattr(x, "sharding", None)
    spec = getattr(s, "spec", None)
    if spec is None:
        return None
    mesh = getattr(s, "mesh", None)
    return {
        "spec": spec_to_lists(spec),
        "mesh": dict(zip(mesh.axis_names,
                         (int(n) for n in mesh.devices.shape)))
        if mesh is not None else None,
    }


# ---------------------------------------------------------------------------
# activations / batch / cache
# ---------------------------------------------------------------------------

def dp_axes_of(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_specs(cfg, batch_shapes, mesh):
    """Shard every batch leaf over the dp axes on its leading (batch) dim."""
    dp = dp_axes_of(mesh)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    def spec_one(leaf):
        base = P(*((dp,) + (None,) * (len(leaf.shape) - 1))) \
            if leaf.ndim >= 1 else P()
        return _sanitize(base, leaf.shape, mesh_shape)

    return jax.tree.map(spec_one, batch_shapes)


def cache_specs(cfg, cache_shapes, mesh, *, tp_axis="model"):
    """KV / recurrent-state cache sharding for decode.

    Layout conventions (see models/transformer.init_cache):
      attn k/v       (L, B, S, KH, hd)   -> batch over dp, SEQ over model
      mla c_kv       (L, B, S, r)        -> batch over dp, seq over model
      rwkv wkv       (L, B, H, P, P)     -> batch over dp, heads over model
      mamba ssd      (L, B, H, P, N)     -> batch over dp, heads over model
      shifts/conv    (L, B, ...)         -> batch over dp

    Sequence-sharded KV turns decode attention into a distributed
    flash-decoding: GSPMD lowers the softmax/contraction over the sharded T
    axis to partial reductions + small all-reduces, so the 524k-token cache
    never materializes on one chip.
    """
    dp = dp_axes_of(mesh)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    def spec_one(path, leaf):
        ks = _keystr(path)
        nd = leaf.ndim
        if re.search(r"(^|/)(k|v|c_kv|k_rope)$", ks):
            # (L, B, S, ...) -> seq over tp
            base = (None, dp, tp_axis) + (None,) * (nd - 3)
        elif re.search(r"(^|/)(wkv|ssd)$", ks):
            base = (None, dp, tp_axis) + (None,) * (nd - 3)
        elif re.search(r"conv$", ks):
            base = (None, dp, None, tp_axis)
        else:  # shifts etc. (L, B, D)
            base = (None, dp) + (None,) * (nd - 2)
        return _sanitize(P(*base), leaf.shape, mesh_shape)

    return jax.tree_util.tree_map_with_path(spec_one, cache_shapes)
