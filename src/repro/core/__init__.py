"""The paper's primary contribution: the DeepSpeed-equivalent distributed
training engine (DDP + grad accumulation + ZeRO stages + Ulysses SP) with
the analytic cluster scaling model used to reproduce the paper's figures."""
from repro.core.engine import DistributedEngine  # noqa: F401
from repro.core.comm_model import (  # noqa: F401
    TPU_V5E,
    Hardware,
    StepModel,
    allreduce_time,
    hierarchical_allreduce_time,
    strong_scaling_times,
    weak_scaling_times,
)
