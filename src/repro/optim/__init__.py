from repro.optim.optimizers import make_optimizer  # noqa: F401
from repro.optim.onebit import make_onebit_optimizer  # noqa: F401
from repro.optim.schedule import make_schedule  # noqa: F401
