"""LR schedules: linear warmup + {cosine, linear, constant} decay."""
from __future__ import annotations

import jax.numpy as jnp


def make_schedule(kind: str, base_lr: float, warmup_steps: int,
                  total_steps: int, final_frac: float = 0.1):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * (step + 1.0) / jnp.maximum(1.0, warmup_steps)
        frac = (step - warmup_steps) / jnp.maximum(
            1.0, total_steps - warmup_steps)
        frac = jnp.clip(frac, 0.0, 1.0)
        if kind == "cosine":
            decay = base_lr * (final_frac + (1 - final_frac)
                               * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        elif kind == "linear":
            decay = base_lr * (1 - (1 - final_frac) * frac)
        elif kind == "constant":
            decay = jnp.full_like(frac, base_lr)
        else:
            raise ValueError(kind)
        return jnp.where(step < warmup_steps, warm, decay)
    return sched
