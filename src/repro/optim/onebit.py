"""1-bit compressed-gradient optimizers with error feedback.

The paper's §V cites DeepSpeed's 1-bit LAMB ("communication efficient
large-batch training with LAMB's convergence") as a follow-up. This module
implements the algorithmic core TPU-natively:

  v_t   = g_t + e_{t-1}            (error feedback)
  q_t   = sign(v_t) · mean|v_t|    (1-bit + per-tensor scale)
  e_t   = v_t - q_t                (carry the compression error)
  update = base_optimizer(q_t)

Under data parallelism the sign tensors are what cross the wire: the ring
all-reduce moves bits + one f32 scale per tensor instead of f32 gradients —
a 32× collective-byte reduction, modeled in
``comm_model.compressed_allreduce_time`` and benchmarked in
``benchmarks/paper_figures.fig6``-style sweeps. (Inside one SPMD program
GSPMD owns the collective, so the compression here is the numerics-visible
part: sign+scale+EF applied to the averaged gradient — the convergence
behavior the paper's reference establishes.)
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer, make_optimizer


class OneBitState(NamedTuple):
    error: Any          # error-feedback buffer, mirrors params
    inner: Any          # wrapped optimizer state


def compress_ef(g, err):
    """(g, err) -> (q, new_err): sign+scale with error feedback."""
    v = g.astype(jnp.float32) + err
    scale = jnp.mean(jnp.abs(v))
    q = jnp.sign(v) * scale
    return q, v - q


def compressed_bytes(nbytes_f32: float) -> float:
    """Wire bytes after 1-bit compression (+f32 scale per tensor,
    amortized away)."""
    return nbytes_f32 / 32.0


def make_onebit_optimizer(base: str = "lamb", **kw) -> Optimizer:
    inner = make_optimizer(base, **kw)

    def init(params):
        err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OneBitState(error=err, inner=inner.init(params))

    def update(grads, state, params, lr):
        qs_and_errs = jax.tree.map(compress_ef, grads, state.error)
        q = jax.tree.map(lambda t: t[0], qs_and_errs,
                         is_leaf=lambda t: isinstance(t, tuple))
        new_err = jax.tree.map(lambda t: t[1], qs_and_errs,
                               is_leaf=lambda t: isinstance(t, tuple))
        new_params, inner_state, gnorm = inner.update(q, state.inner,
                                                      params, lr)
        return new_params, OneBitState(error=new_err, inner=inner_state), \
            gnorm

    return Optimizer(init=init, update=update, name=f"onebit_{base}")
