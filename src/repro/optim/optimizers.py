"""Optimizers: AdamW (paper's default), SGD+momentum and LAMB (the paper's
future-work items §V), pure JAX, ZeRO-shardable (state mirrors param pytree
so the same PartitionSpecs apply)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any           # first moment (adamw/lamb) or momentum (sgd)
    nu: Any           # second moment (adamw/lamb); () for sgd


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable          # (grads, state, params, lr) -> (new_p, state)
    name: str = ""


def _zeros_like_f32(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def make_optimizer(name: str, *, weight_decay=0.01, b1=0.9, b2=0.95,
                   eps=1e-8, momentum=0.9, grad_clip=1.0) -> Optimizer:
    name = name.lower()

    def init(params):
        if name == "sgd":
            return OptState(jnp.zeros((), jnp.int32),
                            _zeros_like_f32(params), ())
        return OptState(jnp.zeros((), jnp.int32), _zeros_like_f32(params),
                        _zeros_like_f32(params))

    def update(grads, state, params, lr):
        if grad_clip > 0:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
        else:
            gnorm = _global_norm(grads)
        step = state.step + 1

        if name == "sgd":
            mu = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state.mu, grads)
            new_p = jax.tree.map(
                lambda p, m: (p.astype(jnp.float32)
                              - lr * (m + weight_decay
                                      * p.astype(jnp.float32))
                              ).astype(p.dtype), params, mu)
            return new_p, OptState(step, mu, ()), gnorm

        # adam moments (shared by adamw / lamb)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1)
                          * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                          * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def adam_dir(m, v, p):
            return m / bc1 / (jnp.sqrt(v / bc2) + eps) \
                + weight_decay * p.astype(jnp.float32)

        if name == "adamw":
            new_p = jax.tree.map(
                lambda p, m, v: (p.astype(jnp.float32)
                                 - lr * adam_dir(m, v, p)).astype(p.dtype),
                params, mu, nu)
        elif name == "lamb":
            # layer-wise trust ratio [You et al.; DeepSpeed 1-bit LAMB ref]
            def lamb_update(p, m, v):
                u = adam_dir(m, v, p)
                pn = jnp.linalg.norm(p.astype(jnp.float32))
                un = jnp.linalg.norm(u)
                trust = jnp.where((pn > 0) & (un > 0), pn / un, 1.0)
                return (p.astype(jnp.float32) - lr * trust * u
                        ).astype(p.dtype)
            new_p = jax.tree.map(lamb_update, params, mu, nu)
        else:
            raise ValueError(f"unknown optimizer {name}")
        return new_p, OptState(step, mu, nu), gnorm

    return Optimizer(init=init, update=update, name=name)
